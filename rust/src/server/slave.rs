//! Slave shard: the inference-facing parameter server (§3.2).
//!
//! Read-optimized: rows hold only the *transformed* serving representation
//! (e.g. FTRL `w`, not `z,n`), fed by the scatter worker consuming the
//! external queue. Fault tolerance is hot multi-replica (§4.2.2) — several
//! identical slave shards serve behind the replica load balancer, each
//! kept consistent by full sync (checkpoint bootstrap) + streaming
//! incremental sync.
//!
//! Serving tables are lock-striped like the master's
//! [`crate::table::StripedSparseTable`]: a pull takes only the read locks
//! of the stripes its ids hash to, and the scatter worker's streaming
//! upserts write-lock one stripe at a time — serving reads never contend
//! with streaming updates on other stripes.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::codec::{Decode, Encode, Reader};
use crate::net::Service;
use crate::proto::{Ack, DensePull, DenseValues, SparsePull, SparseValues, SyncBatch, SyncOp};
use crate::server::methods;
use crate::sync::router::Router;
use crate::sync::transform::Transform;
use crate::table::stripe_of_id;
use crate::util::hash::FxHashMap;
use crate::util::ThreadPool;
use crate::{Error, Result};

/// One serving table: id → transformed row, partitioned into lock stripes.
pub struct ServingTable {
    pub width: usize,
    stripes: Vec<RwLock<FxHashMap<u64, Box<[f32]>>>>,
}

impl ServingTable {
    /// Empty table with fixed serving width and the default stripe count.
    pub fn new(width: usize) -> ServingTable {
        Self::with_stripes(width, crate::table::default_stripe_count())
    }

    /// Empty table with an explicit stripe count (min 1).
    pub fn with_stripes(width: usize, stripes: usize) -> ServingTable {
        ServingTable {
            width,
            stripes: (0..stripes.max(1)).map(|_| RwLock::new(FxHashMap::default())).collect(),
        }
    }

    /// Number of lock stripes.
    pub fn stripe_count(&self) -> usize {
        self.stripes.len()
    }

    /// Owning stripe for an id (same high-bit scheme as the master tables
    /// so stripe choice stays independent of shard routing).
    #[inline]
    fn stripe_of(&self, id: u64) -> usize {
        stripe_of_id(id, self.stripes.len())
    }

    /// Row count (sums stripes; exact at quiesce).
    pub fn len(&self) -> usize {
        self.stripes.iter().map(|s| s.read().unwrap().len()).sum()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.stripes.iter().all(|s| s.read().unwrap().is_empty())
    }

    /// Read rows for `ids` into a flat vec (missing → 0). Small serving
    /// pulls (the latency-critical predict path uses tiny batches) take
    /// the owning stripe's read lock per id with zero grouping
    /// allocations; larger batches group by stripe and take each touched
    /// stripe's read lock once.
    pub fn pull(&self, ids: &[u64]) -> Vec<f32> {
        let width = self.width;
        let mut out = vec![0.0f32; ids.len() * width];
        if ids.len() <= self.stripes.len() {
            for (i, &id) in ids.iter().enumerate() {
                let rows = self.stripes[self.stripe_of(id)].read().unwrap();
                if let Some(row) = rows.get(&id) {
                    out[i * width..(i + 1) * width].copy_from_slice(row);
                }
            }
            return out;
        }
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); self.stripes.len()];
        for (i, &id) in ids.iter().enumerate() {
            groups[self.stripe_of(id)].push(i);
        }
        for (stripe, members) in groups.iter().enumerate() {
            if members.is_empty() {
                continue;
            }
            let rows = self.stripes[stripe].read().unwrap();
            for &i in members {
                if let Some(row) = rows.get(&ids[i]) {
                    out[i * width..(i + 1) * width].copy_from_slice(row);
                }
            }
        }
        out
    }

    /// [`Self::pull`] with the per-stripe reads fanned out over `pool` —
    /// the grouped table×stripe shape of
    /// [`SlaveShard::apply_batches_pooled`] reused on the read side: one
    /// task per busy stripe gathers its members' rows under that stripe's
    /// read lock, prefetching hot stripes in parallel for large predict
    /// batches. Output is identical to [`Self::pull`] for any pool size.
    pub fn pull_pooled(&self, ids: &[u64], pool: &ThreadPool) -> Vec<f32> {
        let width = self.width;
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); self.stripes.len()];
        for (i, &id) in ids.iter().enumerate() {
            groups[self.stripe_of(id)].push(i);
        }
        if groups.iter().filter(|g| !g.is_empty()).count() <= 1 {
            return self.pull(ids);
        }
        // Each task fills a private per-stripe buffer; the scatter into
        // request order happens on the caller thread (no overlapping
        // writes, no unsafe).
        let mut per_stripe: Vec<Vec<f32>> =
            (0..self.stripes.len()).map(|_| Vec::new()).collect();
        {
            let fetch = |stripe: &RwLock<FxHashMap<u64, Box<[f32]>>>,
                         members: &[usize],
                         buf: &mut Vec<f32>| {
                buf.resize(members.len() * width, 0.0);
                let rows = stripe.read().unwrap();
                for (j, &i) in members.iter().enumerate() {
                    if let Some(row) = rows.get(&ids[i]) {
                        buf[j * width..(j + 1) * width].copy_from_slice(row);
                    }
                }
            };
            let fetch = &fetch;
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = per_stripe
                .iter_mut()
                .zip(&self.stripes)
                .zip(&groups)
                .filter(|((_, _), g)| !g.is_empty())
                .map(|((buf, stripe), g)| {
                    Box::new(move || fetch(stripe, g, buf)) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_borrowed(tasks);
        }
        let mut out = vec![0.0f32; ids.len() * width];
        for (stripe, members) in groups.iter().enumerate() {
            for (j, &i) in members.iter().enumerate() {
                out[i * width..(i + 1) * width]
                    .copy_from_slice(&per_stripe[stripe][j * width..(j + 1) * width]);
            }
        }
        out
    }

    fn upsert(&self, id: u64, values: Vec<f32>) {
        self.stripes[self.stripe_of(id)]
            .write()
            .unwrap()
            .insert(id, values.into_boxed_slice());
    }

    fn remove(&self, id: u64) -> bool {
        self.stripes[self.stripe_of(id)].write().unwrap().remove(&id).is_some()
    }

    fn clear(&self) {
        for s in &self.stripes {
            s.write().unwrap().clear();
        }
    }
}

/// Counters exposed through `STATS`.
#[derive(Debug, Default)]
pub struct SlaveMetrics {
    pub pulls: AtomicU64,
    pub applied_entries: AtomicU64,
    pub filtered_entries: AtomicU64,
    pub deletes: AtomicU64,
    pub batches: AtomicU64,
    /// Serving-table stripe write-locks taken by streaming applies. The
    /// coalescing contract: at batch depth D this grows ~D× slower than
    /// applying batch-by-batch (asserted by tests + the sync bench).
    pub stripe_lock_acquisitions: AtomicU64,
}

/// One slave shard replica.
pub struct SlaveShard {
    pub shard_id: u32,
    pub replica_id: u32,
    model: String,
    transform: Arc<dyn Transform>,
    router: Router,
    /// Sparse serving tables: the list is fixed at construction, each
    /// table's rows are guarded by its own lock stripes.
    tables: Vec<(String, ServingTable)>,
    /// Dense tables replace wholesale per sync batch; one lock is fine.
    dense: RwLock<Vec<(String, Vec<f32>)>>,
    /// Model version currently served (checkpoint lineage).
    version: AtomicU64,
    /// Health toggle for failover tests / draining.
    healthy: AtomicBool,
    /// Shared sync pool for pooled applies *and* stripe-prefetching large
    /// serving pulls (`None` = caller-thread reads).
    pool: RwLock<Option<Arc<ThreadPool>>>,
    pub metrics: SlaveMetrics,
}

/// Serving pulls at least this large fan their per-stripe reads over the
/// shared sync pool; smaller pulls (the latency-critical tiny predict
/// batches) stay on the caller thread where the pool round-trip would
/// dominate.
const PULL_PREFETCH_MIN: usize = 256;

impl SlaveShard {
    /// New empty slave shard with the default stripe count. `tables` =
    /// (name, serving width) in model order; `router` is the *slave*
    /// cluster's router.
    pub fn new(
        shard_id: u32,
        replica_id: u32,
        model: &str,
        tables: Vec<(String, usize)>,
        dense: Vec<(String, usize)>,
        transform: Arc<dyn Transform>,
        router: Router,
    ) -> SlaveShard {
        Self::with_stripes(
            shard_id,
            replica_id,
            model,
            tables,
            dense,
            transform,
            router,
            crate::table::default_stripe_count(),
        )
    }

    /// New empty slave shard with an explicit per-table lock-stripe count
    /// (the cluster config's `table_stripes` knob).
    #[allow(clippy::too_many_arguments)]
    pub fn with_stripes(
        shard_id: u32,
        replica_id: u32,
        model: &str,
        tables: Vec<(String, usize)>,
        dense: Vec<(String, usize)>,
        transform: Arc<dyn Transform>,
        router: Router,
        stripes: usize,
    ) -> SlaveShard {
        SlaveShard {
            shard_id,
            replica_id,
            model: model.to_string(),
            transform,
            router,
            tables: tables
                .into_iter()
                .map(|(n, w)| (n, ServingTable::with_stripes(w, stripes)))
                .collect(),
            dense: RwLock::new(dense.into_iter().map(|(n, l)| (n, vec![0.0; l])).collect()),
            version: AtomicU64::new(0),
            healthy: AtomicBool::new(true),
            pool: RwLock::new(None),
            metrics: SlaveMetrics::default(),
        }
    }

    /// Attach the cluster's shared sync pool: large serving pulls then
    /// prefetch their stripes in parallel (grouped exactly like the
    /// coalesced scatter apply).
    pub fn set_sync_pool(&self, pool: Option<Arc<ThreadPool>>) {
        *self.pool.write().unwrap() = pool;
    }

    /// Model name served.
    pub fn model(&self) -> &str {
        &self.model
    }

    /// Serving version (checkpoint id + streaming head).
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// Set the serving version (after full sync / version switch).
    pub fn set_version(&self, v: u64) {
        self.version.store(v, Ordering::Release);
    }

    /// Health controls (used by the balancer and failure injection).
    pub fn set_healthy(&self, ok: bool) {
        self.healthy.store(ok, Ordering::Release);
    }

    /// True when serving.
    pub fn is_healthy(&self) -> bool {
        self.healthy.load(Ordering::Acquire)
    }

    /// Apply one streaming sync batch: filter ids to this shard, transform
    /// master rows to serving rows, upsert/delete; dense batches replace
    /// values wholesale. Idempotent (full-value upserts, §4.1d).
    pub fn apply_batch(&self, batch: &SyncBatch) -> Result<()> {
        self.apply_batch_pooled(batch, None)
    }

    /// [`Self::apply_batch`] with the per-stripe work fanned out over
    /// `pool` (the cluster's shared sync pool). Delegates to the
    /// coalescing entry point with a run of one.
    pub fn apply_batch_pooled(&self, batch: &SyncBatch, pool: Option<&ThreadPool>) -> Result<()> {
        self.apply_batches_pooled(std::slice::from_ref(batch), pool)
    }

    /// Apply one dense-snapshot batch (values replace wholesale).
    fn apply_dense(&self, batch: &SyncBatch) -> Result<()> {
        let mut dense = self.dense.write().unwrap();
        let Some(t) = dense.iter_mut().find(|(n, _)| *n == batch.table) else {
            // Data screening (§4.1.4b): this slave type does not serve
            // the table — e.g. an embedding slave ignoring the tower.
            self.metrics.filtered_entries.fetch_add(1, Ordering::Relaxed);
            return Ok(());
        };
        if t.1.len() != batch.dense.len() {
            return Err(Error::Codec(format!(
                "dense sync {}: len {} != {}",
                batch.table,
                batch.dense.len(),
                t.1.len()
            )));
        }
        t.1.copy_from_slice(&batch.dense);
        Ok(())
    }

    /// Apply a run of coalesced streaming batches — the scatter worker's
    /// hot path (it hands over everything the queue had available).
    ///
    /// Entries are grouped per serving table × lock stripe across *all*
    /// batches up front, **in batch order**, so a later batch's op for an
    /// id lands after an earlier one's exactly as sequential application
    /// would (last write wins). Each group's transform runs outside any
    /// lock, and each non-empty table×stripe group then takes its write
    /// lock exactly once regardless of how many batches fed it: at queue
    /// depth D the stripe-lock acquisitions per applied row drop ~D×
    /// versus batch-by-batch application
    /// ([`SlaveMetrics::stripe_lock_acquisitions`] counts them; the sync
    /// bench asserts the decrease). With a pool, distinct table×stripe
    /// groups transform+apply concurrently. Dense batches apply inline in
    /// arrival order.
    ///
    /// On a transform/validation error the failing group drops its
    /// entries and the first error is returned after everything else has
    /// landed. A batch is *not* retried — the scatter has already
    /// advanced past it (deterministically bad batches must not wedge the
    /// stream) — so dropped rows stay stale until a later update
    /// re-dirties them or a full sync rebuilds the replica.
    pub fn apply_batches_pooled(
        &self,
        batches: &[SyncBatch],
        pool: Option<&ThreadPool>,
    ) -> Result<()> {
        if batches.is_empty() {
            return Ok(());
        }
        self.metrics.batches.fetch_add(batches.len() as u64, Ordering::Relaxed);
        // One routing snapshot for the whole run: per-id routes stay
        // consistent even if a slot-map install lands mid-apply.
        let route = self.router.snapshot();
        let first_err: Mutex<Option<Error>> = Mutex::new(None);
        // One coalesced work unit per distinct sparse table in the run.
        struct TableRun<'a> {
            name: &'a str,
            table: &'a ServingTable,
            /// Per stripe: (batch idx, entry idx), in batch order.
            groups: Vec<Vec<(u32, u32)>>,
        }
        let mut runs: Vec<TableRun> = Vec::new();
        let mut filtered = 0u64;
        for (bi, batch) in batches.iter().enumerate() {
            if !batch.dense.is_empty() {
                if let Err(e) = self.apply_dense(batch) {
                    first_err.lock().unwrap().get_or_insert(e);
                }
                continue;
            }
            let Some(width) = self.transform.serving_width(&batch.table) else {
                // Screened-out table for this slave type.
                filtered += batch.entries.len() as u64;
                continue;
            };
            let ri = match runs.iter().position(|r| r.name == batch.table) {
                Some(ri) => ri,
                None => {
                    let Some((name, table)) =
                        self.tables.iter().find(|(n, _)| *n == batch.table)
                    else {
                        first_err
                            .lock()
                            .unwrap()
                            .get_or_insert(Error::NotFound(format!(
                                "serving table {}",
                                batch.table
                            )));
                        continue;
                    };
                    debug_assert_eq!(table.width, width);
                    runs.push(TableRun {
                        name: name.as_str(),
                        table,
                        groups: vec![Vec::new(); table.stripe_count()],
                    });
                    runs.len() - 1
                }
            };
            let run = &mut runs[ri];
            for (ei, entry) in batch.entries.iter().enumerate() {
                if route.shard_of(entry.id) != self.shard_id {
                    filtered += 1;
                    continue;
                }
                run.groups[run.table.stripe_of(entry.id)].push((bi as u32, ei as u32));
            }
        }
        self.metrics.filtered_entries.fetch_add(filtered, Ordering::Relaxed);
        let apply_group = |run: &TableRun, stripe: usize, idxs: &[(u32, u32)]| {
            let mut ops: Vec<(u64, Option<Vec<f32>>)> = Vec::with_capacity(idxs.len());
            for &(bi, ei) in idxs {
                let entry = &batches[bi as usize].entries[ei as usize];
                match &entry.op {
                    SyncOp::Upsert(row) => match self.transform.transform(run.name, row) {
                        Ok(Some(serving)) => ops.push((entry.id, Some(serving))),
                        Ok(None) => {}
                        Err(e) => {
                            first_err.lock().unwrap().get_or_insert(e);
                            return;
                        }
                    },
                    SyncOp::Delete => ops.push((entry.id, None)),
                }
            }
            if ops.is_empty() {
                return;
            }
            self.metrics.stripe_lock_acquisitions.fetch_add(1, Ordering::Relaxed);
            let mut applied = 0u64;
            let mut deleted = 0u64;
            let mut rows = run.table.stripes[stripe].write().unwrap();
            for (id, op) in ops {
                match op {
                    Some(serving) => {
                        rows.insert(id, serving.into_boxed_slice());
                        applied += 1;
                    }
                    None => {
                        if rows.remove(&id).is_some() {
                            deleted += 1;
                        }
                        applied += 1;
                    }
                }
            }
            drop(rows);
            self.metrics.applied_entries.fetch_add(applied, Ordering::Relaxed);
            self.metrics.deletes.fetch_add(deleted, Ordering::Relaxed);
        };
        // Flatten to (table run, stripe) work items across all tables.
        let mut work: Vec<(usize, usize)> = Vec::new();
        for (ri, run) in runs.iter().enumerate() {
            for (s, g) in run.groups.iter().enumerate() {
                if !g.is_empty() {
                    work.push((ri, s));
                }
            }
        }
        match pool {
            Some(pool) if work.len() > 1 => {
                let apply_group = &apply_group;
                let runs = &runs;
                let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = work
                    .iter()
                    .map(|&(ri, s)| {
                        Box::new(move || apply_group(&runs[ri], s, &runs[ri].groups[s]))
                            as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                pool.run_borrowed(tasks);
            }
            _ => {
                for &(ri, s) in &work {
                    apply_group(&runs[ri], s, &runs[ri].groups[s]);
                }
            }
        }
        match first_err.into_inner().unwrap() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Filter one master row to this shard, transform it and upsert the
    /// serving form — the per-row step shared by full sync and delta
    /// apply. Returns true when a row landed. `route` is one consistent
    /// slot-map snapshot for the whole pass.
    fn sync_row(
        &self,
        route: &crate::reshard::SlotMap,
        tbl_idx: Option<usize>,
        serving: Option<usize>,
        name: &str,
        id: u64,
        values: &[f32],
    ) -> Result<bool> {
        if serving.is_none() || route.shard_of(id) != self.shard_id {
            return Ok(false);
        }
        if let (Some(idx), Some(out)) = (tbl_idx, self.transform.transform(name, values)?) {
            self.tables[idx].1.upsert(id, out);
            return Ok(true);
        }
        Ok(false)
    }

    /// Dense tail shared by snapshot and delta chunks: (name, version,
    /// values, acc) per table; unknown names and length mismatches are
    /// skipped (data screening).
    fn decode_dense_tail(&self, r: &mut Reader) -> Result<()> {
        let n_dense = r.get_varint()? as usize;
        let mut dense = self.dense.write().unwrap();
        for _ in 0..n_dense {
            let name = r.get_str()?;
            let _version = r.get_u64()?;
            let values = r.get_f32_slice()?;
            let _acc = r.get_f32_slice()?;
            if let Some(t) = dense.iter_mut().find(|(n, _)| *n == name) {
                if t.1.len() == values.len() {
                    t.1.copy_from_slice(&values);
                }
            }
        }
        Ok(())
    }

    /// Full synchronization (§4.1, §4.2.2): bootstrap this replica from a
    /// master-shard checkpoint snapshot — filter ids to this slave shard,
    /// transform each row. Call once per master shard snapshot.
    pub fn full_sync_from_snapshot(&self, snapshot: &[u8]) -> Result<usize> {
        self.full_sync_from_snapshot_owned(snapshot, None)
    }

    /// Like [`Self::full_sync_from_snapshot`] with a master-side owner
    /// filter: `owner` = (current master slot map, chunk's source shard).
    /// Checkpoint chunks sealed *before* a slot migration still carry the
    /// moved rows at pre-move values; skipping rows the source shard no
    /// longer owns stops a chain rebuild from resurrecting them over the
    /// new owner's authoritative copy.
    pub fn full_sync_from_snapshot_owned(
        &self,
        snapshot: &[u8],
        owner: Option<(&crate::reshard::SlotMap, u32)>,
    ) -> Result<usize> {
        let route = self.router.snapshot();
        let mut r = Reader::new(snapshot);
        let _src_shard = r.get_u32()?;
        let n_sparse = r.get_varint()? as usize;
        let mut loaded = 0usize;
        for _ in 0..n_sparse {
            // Decode the master table inline (name, dim, width, rows).
            let name = r.get_str()?;
            let _dim = r.get_u32()?;
            let width = r.get_u32()? as usize;
            let serving = self.transform.serving_width(&name);
            let tbl_idx = self.tables.iter().position(|(n, _)| *n == name);
            let count = r.get_varint()? as usize;
            for _ in 0..count {
                let id = r.get_varint()?;
                let _last_access = r.get_varint()?;
                let _updates = r.get_u32()?;
                let values = r.get_f32_slice()?;
                if values.len() != width {
                    return Err(Error::Checkpoint(format!("row {id} width {}", values.len())));
                }
                if let Some((map, src)) = owner {
                    if map.shard_of(id) != src {
                        continue;
                    }
                }
                if self.sync_row(&route, tbl_idx, serving, &name, id, &values)? {
                    loaded += 1;
                }
            }
        }
        self.decode_dense_tail(&mut r)?;
        Ok(loaded)
    }

    /// Warm-start continuation: apply one incremental delta chunk
    /// (written by `MasterShard::encode_delta`) on top of a base full
    /// sync — filter ids to this slave shard, transform dirty rows to
    /// serving form, apply tombstones, take dense state wholesale.
    /// Returns rows upserted + deleted here.
    pub fn apply_delta_snapshot(&self, chunk: &[u8]) -> Result<usize> {
        self.apply_delta_snapshot_owned(chunk, None)
    }

    /// Like [`Self::apply_delta_snapshot`] with the same master-side
    /// owner filter as [`Self::full_sync_from_snapshot_owned`]: upserts
    /// *and tombstones* from a source shard that lost the slot are
    /// skipped (a stale tombstone deleting the new owner's live row is
    /// just as wrong as a stale upsert).
    pub fn apply_delta_snapshot_owned(
        &self,
        chunk: &[u8],
        owner: Option<(&crate::reshard::SlotMap, u32)>,
    ) -> Result<usize> {
        let route = self.router.snapshot();
        let mut r = Reader::new(chunk);
        let _src_shard = r.get_u32()?;
        let _since = r.get_varint()?;
        let n_sparse = r.get_varint()? as usize;
        let mut applied = 0usize;
        for _ in 0..n_sparse {
            let name = r.get_str()?;
            let _dim = r.get_u32()?;
            let width = r.get_u32()? as usize;
            let serving = self.transform.serving_width(&name);
            let tbl_idx = self.tables.iter().position(|(n, _)| *n == name);
            let n_upserts = r.get_varint()? as usize;
            for _ in 0..n_upserts {
                let id = r.get_varint()?;
                let _last_access = r.get_varint()?;
                let _updates = r.get_u32()?;
                let values = r.get_f32_slice()?;
                if values.len() != width {
                    return Err(Error::Checkpoint(format!(
                        "delta row {id} width {}",
                        values.len()
                    )));
                }
                if let Some((map, src)) = owner {
                    if map.shard_of(id) != src {
                        continue;
                    }
                }
                if self.sync_row(&route, tbl_idx, serving, &name, id, &values)? {
                    applied += 1;
                }
            }
            let n_deletes = r.get_varint()? as usize;
            for _ in 0..n_deletes {
                let id = r.get_varint()?;
                if route.shard_of(id) != self.shard_id {
                    continue;
                }
                if let Some((map, src)) = owner {
                    if map.shard_of(id) != src {
                        continue;
                    }
                }
                if let Some(idx) = tbl_idx {
                    if self.tables[idx].1.remove(id) {
                        applied += 1;
                    }
                }
            }
        }
        self.decode_dense_tail(&mut r)?;
        Ok(applied)
    }

    /// Drop all rows (before a full re-sync on version switch).
    pub fn clear(&self) {
        for (_, t) in self.tables.iter() {
            t.clear();
        }
        for (_, d) in self.dense.write().unwrap().iter_mut() {
            d.iter_mut().for_each(|x| *x = 0.0);
        }
    }

    /// Serve a sparse pull (serving representation). Touches only the
    /// stripes the requested ids hash to, in read mode.
    pub fn sparse_pull(&self, req: &SparsePull) -> Result<SparseValues> {
        if !self.is_healthy() {
            return Err(Error::Unavailable(format!(
                "slave {}/{} draining",
                self.shard_id, self.replica_id
            )));
        }
        self.metrics.pulls.fetch_add(1, Ordering::Relaxed);
        let t = self
            .tables
            .iter()
            .find(|(n, _)| *n == req.table)
            .ok_or_else(|| Error::NotFound(format!("serving table {}", req.table)))?;
        let pool = self.pool.read().unwrap().clone();
        let values = match pool {
            Some(pool) if req.ids.len() >= PULL_PREFETCH_MIN && t.1.stripe_count() > 1 => {
                t.1.pull_pooled(&req.ids, &pool)
            }
            _ => t.1.pull(&req.ids),
        };
        Ok(SparseValues { width: t.1.width as u32, values })
    }

    /// Serve a dense pull.
    pub fn dense_pull(&self, req: &DensePull) -> Result<DenseValues> {
        if !self.is_healthy() {
            return Err(Error::Unavailable("slave draining".into()));
        }
        let dense = self.dense.read().unwrap();
        let t = dense
            .iter()
            .find(|(n, _)| *n == req.table)
            .ok_or_else(|| Error::NotFound(format!("dense table {}", req.table)))?;
        Ok(DenseValues { model: req.model.clone(), table: req.table.clone(), values: t.1.clone() })
    }

    /// Rows currently served across tables.
    pub fn total_rows(&self) -> usize {
        self.tables.iter().map(|(_, t)| t.len()).sum()
    }

    /// Register this replica's observability series (serving counters,
    /// row gauge, stripe-lock counter) under `role`/`shard`/`replica`.
    /// Samplers hold a `Weak`, so a dropped replica's series disappear
    /// from scrapes.
    pub fn register_metrics(self: &Arc<Self>, role: &str) {
        use crate::metrics::register_fn;
        let labels = [
            ("role", role.to_string()),
            ("shard", self.shard_id.to_string()),
            ("replica", self.replica_id.to_string()),
        ];
        let counters: [(&'static str, fn(&SlaveMetrics) -> &AtomicU64); 4] = [
            ("weips_slave_pulls_total", |m| &m.pulls),
            ("weips_slave_applied_entries_total", |m| &m.applied_entries),
            ("weips_slave_filtered_entries_total", |m| &m.filtered_entries),
            ("weips_stripe_lock_acquisitions_total", |m| &m.stripe_lock_acquisitions),
        ];
        for (name, get) in counters {
            let weak = Arc::downgrade(self);
            register_fn(
                name,
                &labels,
                Box::new(move || {
                    weak.upgrade().map(|s| get(&s.metrics).load(Ordering::Relaxed) as f64)
                }),
            );
        }
        let weak = Arc::downgrade(self);
        register_fn(
            "weips_slave_rows",
            &labels,
            Box::new(move || weak.upgrade().map(|s| s.total_rows() as f64)),
        );
    }

    fn stats_json(&self) -> String {
        format!(
            r#"{{"shard":{},"replica":{},"rows":{},"version":{},"pulls":{},"applied":{},"filtered":{},"healthy":{}}}"#,
            self.shard_id,
            self.replica_id,
            self.total_rows(),
            self.version(),
            self.metrics.pulls.load(Ordering::Relaxed),
            self.metrics.applied_entries.load(Ordering::Relaxed),
            self.metrics.filtered_entries.load(Ordering::Relaxed),
            self.is_healthy(),
        )
    }
}

/// RPC facade for a slave shard.
pub struct SlaveService {
    pub shard: Arc<SlaveShard>,
}

impl Service for SlaveService {
    fn call(&self, method: u16, payload: &[u8]) -> Result<Vec<u8>> {
        match method {
            methods::SPARSE_PULL => {
                let req = SparsePull::from_bytes(payload)?;
                Ok(self.shard.sparse_pull(&req)?.to_bytes())
            }
            methods::DENSE_PULL => {
                let req = DensePull::from_bytes(payload)?;
                Ok(self.shard.dense_pull(&req)?.to_bytes())
            }
            methods::STATS => Ok(self.shard.stats_json().into_bytes()),
            methods::PING => {
                if self.shard.is_healthy() {
                    Ok(Ack::ok().to_bytes())
                } else {
                    Err(Error::Unavailable("unhealthy".into()))
                }
            }
            m => Err(Error::Rpc(format!("slave: unknown method {m}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{Ftrl, FtrlHyper};
    use crate::proto::SyncEntry;
    use crate::sync::transform::ServingWeights;

    fn transform() -> Arc<dyn Transform> {
        let ftrl: Arc<dyn crate::optim::Optimizer> = Arc::new(Ftrl::new(FtrlHyper::default()));
        Arc::new(ServingWeights::new(vec![
            ("w".into(), ftrl.clone(), 1),
            ("v".into(), ftrl, 2),
        ]))
    }

    fn slave(shard: u32, shards: u32) -> SlaveShard {
        SlaveShard::new(
            shard,
            0,
            "ctr",
            vec![("w".into(), 1), ("v".into(), 2)],
            vec![("bias".into(), 1)],
            transform(),
            Router::new(shards),
        )
    }

    fn batch(table: &str, entries: Vec<SyncEntry>) -> SyncBatch {
        SyncBatch {
            model: "ctr".into(),
            table: table.into(),
            shard: 0,
            seq: 1,
            created_ms: 0,
            entries,
            dense: vec![],
        }
    }

    #[test]
    fn apply_upsert_transforms_to_serving() {
        let s = slave(0, 1);
        // FTRL row (z, n, w) dim 1: serving = w = -0.25.
        s.apply_batch(&batch(
            "w",
            vec![SyncEntry { id: 42, op: SyncOp::Upsert(vec![2.0, 1.0, -0.25]) }],
        ))
        .unwrap();
        let out = s
            .sparse_pull(&SparsePull {
                model: "ctr".into(),
                table: "w".into(),
                ids: vec![42, 43],
                slot: "w".into(),
            })
            .unwrap();
        assert_eq!(out.values, vec![-0.25, 0.0]);
    }

    #[test]
    fn apply_filters_foreign_ids() {
        let s = slave(1, 4);
        let router = Router::new(4);
        let mine: u64 = (0..1000).find(|id| router.shard_of(*id) == 1).unwrap();
        let foreign: u64 = (0..1000).find(|id| router.shard_of(*id) == 0).unwrap();
        s.apply_batch(&batch(
            "w",
            vec![
                SyncEntry { id: mine, op: SyncOp::Upsert(vec![1.0, 1.0, 0.5]) },
                SyncEntry { id: foreign, op: SyncOp::Upsert(vec![1.0, 1.0, 0.9]) },
            ],
        ))
        .unwrap();
        assert_eq!(s.total_rows(), 1);
        assert_eq!(s.metrics.filtered_entries.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn apply_delete_removes_row() {
        let s = slave(0, 1);
        s.apply_batch(&batch("w", vec![SyncEntry { id: 7, op: SyncOp::Upsert(vec![0.0, 0.0, 0.3]) }]))
            .unwrap();
        assert_eq!(s.total_rows(), 1);
        s.apply_batch(&batch("w", vec![SyncEntry { id: 7, op: SyncOp::Delete }])).unwrap();
        assert_eq!(s.total_rows(), 0);
        assert_eq!(s.metrics.deletes.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn pooled_apply_matches_sequential() {
        let pool = ThreadPool::new(4, "scatter-test");
        let entries: Vec<SyncEntry> = (0..500u64)
            .map(|id| SyncEntry {
                id,
                op: SyncOp::Upsert(vec![2.0, 1.0, -0.25 - id as f32 * 1e-3]),
            })
            .chain((0..10u64).map(|id| SyncEntry { id: id * 7, op: SyncOp::Delete }))
            .collect();
        let b = batch("w", entries);
        let seq = slave(0, 1);
        seq.apply_batch(&b).unwrap();
        let par = slave(0, 1);
        par.apply_batch_pooled(&b, Some(&pool)).unwrap();
        assert_eq!(seq.total_rows(), par.total_rows());
        let ids: Vec<u64> = (0..500).collect();
        let a = seq
            .sparse_pull(&SparsePull {
                model: "ctr".into(),
                table: "w".into(),
                ids: ids.clone(),
                slot: "w".into(),
            })
            .unwrap();
        let c = par
            .sparse_pull(&SparsePull { model: "ctr".into(), table: "w".into(), ids, slot: "w".into() })
            .unwrap();
        assert_eq!(a, c, "pooled scatter apply diverged from sequential");
        assert_eq!(
            seq.metrics.applied_entries.load(Ordering::Relaxed),
            par.metrics.applied_entries.load(Ordering::Relaxed)
        );
    }

    #[test]
    fn pooled_pull_prefetch_matches_sequential() {
        let pool = Arc::new(ThreadPool::new(4, "pull-test"));
        let s = slave(0, 1);
        s.set_sync_pool(Some(pool.clone()));
        let entries: Vec<SyncEntry> = (0..1000u64)
            .map(|id| SyncEntry { id, op: SyncOp::Upsert(vec![2.0, 1.0, id as f32 * 1e-3]) })
            .collect();
        s.apply_batch(&batch("w", entries)).unwrap();
        // Large pull: the pooled prefetch path (includes missing ids).
        let ids: Vec<u64> = (0..1200).collect();
        let table = &s.tables.iter().find(|(n, _)| n == "w").unwrap().1;
        let seq = table.pull(&ids);
        let pooled = table.pull_pooled(&ids, &pool);
        assert_eq!(seq, pooled, "pooled prefetch diverged from sequential pull");
        // End to end through sparse_pull (len >= prefetch floor engages
        // the pool; a tiny pull takes the per-id path): both correct.
        let big = s
            .sparse_pull(&SparsePull {
                model: "ctr".into(),
                table: "w".into(),
                ids: ids.clone(),
                slot: "w".into(),
            })
            .unwrap();
        assert_eq!(big.values, seq);
        let small = s
            .sparse_pull(&SparsePull {
                model: "ctr".into(),
                table: "w".into(),
                ids: vec![5, 5000],
                slot: "w".into(),
            })
            .unwrap();
        assert_eq!(small.values, vec![5.0 * 1e-3, 0.0]);
    }

    #[test]
    fn coalesced_apply_matches_sequential_and_amortizes_locks() {
        // D batches over overlapping id ranges, including a later batch
        // overwriting an earlier one's ids and deleting some.
        let depth = 6u64;
        let batches: Vec<SyncBatch> = (0..depth)
            .map(|d| {
                let entries: Vec<SyncEntry> = (0..200u64)
                    .map(|id| {
                        if d == depth - 1 && id % 11 == 0 {
                            SyncEntry { id, op: SyncOp::Delete }
                        } else {
                            SyncEntry {
                                id,
                                op: SyncOp::Upsert(vec![2.0, 1.0, -0.2 - (d as f32) * 0.1]),
                            }
                        }
                    })
                    .collect();
                batch("w", entries)
            })
            .collect();
        let seq = slave(0, 1);
        for b in &batches {
            seq.apply_batch(b).unwrap();
        }
        let coalesced = slave(0, 1);
        coalesced.apply_batches_pooled(&batches, None).unwrap();
        let pool = ThreadPool::new(4, "coalesce-test");
        let pooled = slave(0, 1);
        pooled.apply_batches_pooled(&batches, Some(&pool)).unwrap();

        let ids: Vec<u64> = (0..200).collect();
        let pull = |s: &SlaveShard| {
            s.sparse_pull(&SparsePull {
                model: "ctr".into(),
                table: "w".into(),
                ids: ids.clone(),
                slot: "w".into(),
            })
            .unwrap()
        };
        assert_eq!(pull(&seq), pull(&coalesced), "coalesced apply diverged");
        assert_eq!(pull(&seq), pull(&pooled), "pooled coalesced apply diverged");
        assert_eq!(seq.total_rows(), coalesced.total_rows());

        // The acceptance criterion: lock acquisitions per applied row
        // strictly decrease at batch depth > 1.
        let seq_locks = seq.metrics.stripe_lock_acquisitions.load(Ordering::Relaxed);
        let co_locks = coalesced.metrics.stripe_lock_acquisitions.load(Ordering::Relaxed);
        let applied = seq.metrics.applied_entries.load(Ordering::Relaxed);
        assert_eq!(applied, coalesced.metrics.applied_entries.load(Ordering::Relaxed));
        assert!(applied > 0);
        assert!(
            co_locks < seq_locks,
            "coalescing did not amortize locks: {co_locks} vs {seq_locks}"
        );
        // One table, D batches: sequential takes stripes-per-batch locks
        // per batch; the coalesced run takes each busy stripe once.
        assert!(co_locks <= seq.tables[0].1.stripe_count() as u64);
    }

    #[test]
    fn coalesced_run_spanning_tables_and_dense_applies_everything() {
        let s = slave(0, 1);
        let mut dense_batch = batch("bias", vec![]);
        dense_batch.dense = vec![0.5];
        let run = vec![
            batch("w", vec![SyncEntry { id: 1, op: SyncOp::Upsert(vec![2.0, 1.0, 0.25]) }]),
            dense_batch,
            batch("v", vec![SyncEntry {
                id: 2,
                op: SyncOp::Upsert(vec![0., 0., 1., 1., 0.5, -0.5]),
            }]),
        ];
        s.apply_batches_pooled(&run, None).unwrap();
        assert_eq!(s.total_rows(), 2);
        let d = s.dense_pull(&DensePull { model: "ctr".into(), table: "bias".into() }).unwrap();
        assert_eq!(d.values, vec![0.5]);
        assert_eq!(s.metrics.batches.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn replay_is_idempotent() {
        let s = slave(0, 1);
        let b = batch(
            "v",
            vec![SyncEntry { id: 9, op: SyncOp::Upsert(vec![0., 0., 1., 1., 0.5, -0.5]) }],
        );
        s.apply_batch(&b).unwrap();
        let first = s
            .sparse_pull(&SparsePull { model: "ctr".into(), table: "v".into(), ids: vec![9], slot: "w".into() })
            .unwrap();
        // Apply the same batch again (queue replay after downgrade).
        s.apply_batch(&b).unwrap();
        s.apply_batch(&b).unwrap();
        let third = s
            .sparse_pull(&SparsePull { model: "ctr".into(), table: "v".into(), ids: vec![9], slot: "w".into() })
            .unwrap();
        assert_eq!(first, third);
        assert_eq!(s.total_rows(), 1);
    }

    #[test]
    fn dense_sync_replaces_values() {
        let s = slave(0, 1);
        let mut b = batch("bias", vec![]);
        b.dense = vec![0.75];
        s.apply_batch(&b).unwrap();
        let d = s
            .dense_pull(&DensePull { model: "ctr".into(), table: "bias".into() })
            .unwrap();
        assert_eq!(d.values, vec![0.75]);
        // Wrong length rejected.
        b.dense = vec![1.0, 2.0];
        assert!(s.apply_batch(&b).is_err());
    }

    #[test]
    fn unhealthy_rejects_reads() {
        let s = slave(0, 1);
        s.set_healthy(false);
        assert!(s
            .sparse_pull(&SparsePull { model: "ctr".into(), table: "w".into(), ids: vec![1], slot: "w".into() })
            .is_err());
        s.set_healthy(true);
        assert!(s
            .sparse_pull(&SparsePull { model: "ctr".into(), table: "w".into(), ids: vec![1], slot: "w".into() })
            .is_ok());
    }

    #[test]
    fn full_sync_from_master_snapshot() {
        use crate::config::{ModelKind, ModelSpec};
        use crate::proto::SparsePush;
        use crate::runtime::ModelConfig;
        use crate::server::master::MasterShard;
        use crate::util::clock::ManualClock;

        let cfg = ModelConfig {
            batch_train: 8,
            batch_predict: 2,
            fields: 4,
            dim: 2,
            hidden: 8,
            ftrl_block_rows: 64,
            ftrl_alpha: 0.05,
            ftrl_beta: 1.0,
            ftrl_l1: 1.0,
            ftrl_l2: 1.0,
        };
        let spec = ModelSpec::derive("ctr", ModelKind::Fm, &cfg);
        let master = MasterShard::new(
            0,
            spec,
            None,
            1,
            Arc::new(ManualClock::new(0)),
        )
        .unwrap();
        for i in 0..100u64 {
            master
                .sparse_push(&SparsePush {
                    model: "ctr".into(),
                    table: "w".into(),
                    ids: vec![i],
                    grads: vec![2.0], // |z| > l1 -> nonzero w
                })
                .unwrap();
        }
        let snap = master.snapshot();

        // Two slave shards split the id space.
        let s0 = slave(0, 2);
        let s1 = slave(1, 2);
        let l0 = s0.full_sync_from_snapshot(&snap).unwrap();
        let l1 = s1.full_sync_from_snapshot(&snap).unwrap();
        assert_eq!(l0 + l1, 100);
        assert!(l0 > 20 && l1 > 20, "balance: {l0}/{l1}");
        // Serving value matches the master's w slot.
        let router = Router::new(2);
        let id = (0..100).find(|i| router.shard_of(*i) == 0).unwrap();
        let mw = master
            .sparse_pull(&SparsePull { model: "ctr".into(), table: "w".into(), ids: vec![id], slot: "w".into() })
            .unwrap();
        let sw = s0
            .sparse_pull(&SparsePull { model: "ctr".into(), table: "w".into(), ids: vec![id], slot: "w".into() })
            .unwrap();
        assert_eq!(mw.values, sw.values);
        assert!(mw.values[0] != 0.0);
    }

    #[test]
    fn delta_snapshot_continues_a_full_sync() {
        use crate::config::{ModelKind, ModelSpec};
        use crate::proto::SparsePush;
        use crate::runtime::ModelConfig;
        use crate::server::master::MasterShard;
        use crate::util::clock::ManualClock;

        let cfg = ModelConfig {
            batch_train: 8,
            batch_predict: 2,
            fields: 4,
            dim: 2,
            hidden: 8,
            ftrl_block_rows: 64,
            ftrl_alpha: 0.05,
            ftrl_beta: 1.0,
            ftrl_l1: 1.0,
            ftrl_l2: 1.0,
        };
        let spec = ModelSpec::derive("ctr", ModelKind::Fm, &cfg);
        let clock = ManualClock::new(0);
        let master = MasterShard::new(0, spec, None, 1, Arc::new(clock.clone())).unwrap();
        let push = |id: u64, g: f32| {
            master
                .sparse_push(&SparsePush {
                    model: "ctr".into(),
                    table: "w".into(),
                    ids: vec![id],
                    grads: vec![g],
                })
                .unwrap()
        };
        for i in 0..60u64 {
            push(i, 2.0);
        }
        let s = slave(0, 1);
        s.full_sync_from_snapshot(&master.snapshot()).unwrap();
        assert_eq!(s.total_rows(), 60);
        // Post-base window: refresh two rows, expire the other 58.
        let cut = master.cut_epoch();
        clock.advance(10_000);
        push(1, 3.0);
        push(2, 3.0);
        assert_eq!(master.expire_features(5_000), 58);
        let chunk = master.encode_delta(cut);
        assert_eq!(chunk.deletes, 58);
        s.apply_delta_snapshot(&chunk.bytes).unwrap();
        assert_eq!(s.total_rows(), 2);
        // Served value tracks the master's current serving weight.
        let pull = |ids: Vec<u64>| SparsePull {
            model: "ctr".into(),
            table: "w".into(),
            ids,
            slot: "w".into(),
        };
        let mw = master.sparse_pull(&pull(vec![1, 2])).unwrap();
        let sw = s.sparse_pull(&pull(vec![1, 2])).unwrap();
        assert_eq!(mw.values, sw.values);
        // Hostile input: a truncated chunk errors, never panics.
        assert!(s.apply_delta_snapshot(&chunk.bytes[..10]).is_err());
    }

    #[test]
    fn clear_resets_state() {
        let s = slave(0, 1);
        s.apply_batch(&batch("w", vec![SyncEntry { id: 1, op: SyncOp::Upsert(vec![0., 0., 0.1]) }]))
            .unwrap();
        let mut b = batch("bias", vec![]);
        b.dense = vec![0.9];
        s.apply_batch(&b).unwrap();
        s.clear();
        assert_eq!(s.total_rows(), 0);
        let d = s.dense_pull(&DensePull { model: "ctr".into(), table: "bias".into() }).unwrap();
        assert_eq!(d.values, vec![0.0]);
    }
}
