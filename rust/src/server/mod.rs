//! Server role (§3.2): master (training-facing) and slave (serving-facing)
//! parameter-server shards, plus their RPC method tables.

pub mod master;
pub mod slave;

/// RPC method ids shared by master and slave services.
pub mod methods {
    /// `SparsePull -> SparseValues`
    pub const SPARSE_PULL: u16 = 1;
    /// `SparsePush -> Ack` (master only)
    pub const SPARSE_PUSH: u16 = 2;
    /// `DensePull -> DenseValues`
    pub const DENSE_PULL: u16 = 3;
    /// `DenseValues (grads) -> Ack` (master only)
    pub const DENSE_PUSH: u16 = 4;
    /// `CkptRequest -> Ack` (master only)
    pub const SAVE_CKPT: u16 = 5;
    /// `CkptRequest -> Ack` (master only)
    pub const LOAD_CKPT: u16 = 6;
    /// `() -> Stats (json)`
    pub const STATS: u16 = 7;
    /// health probe: `() -> Ack`
    pub const PING: u16 = 8;
    /// `SlotPull -> raw slot-chunk bytes` (master only; migration donor)
    pub const MIGRATE_PULL: u16 = 9;
    /// `raw slot-chunk bytes -> Ack` (master only; migration recipient)
    pub const MIGRATE_APPLY: u16 = 10;
    /// `SlotSeal -> Ack` (master only; empty slot list = unseal)
    pub const SEAL_SLOTS: u16 = 11;
    /// `() -> u64 LE` current routing epoch (master only)
    pub const ROUTE_EPOCH: u16 = 12;
    /// `SlotMap bytes -> Ack` cutover install (master only)
    pub const INSTALL_SLOT_MAP: u16 = 13;
    /// `SlotSeal -> Ack` post-cutover release: purge moved slots + unseal
    /// (master only)
    pub const RELEASE_SLOTS: u16 = 14;
    /// `() -> SlotMap bytes` published routing table (master only; fresh
    /// slaves and remote trainers bootstrap from it, and refresh it on a
    /// `StaleRoute` NACK instead of restarting)
    pub const FETCH_SLOT_MAP: u16 = 15;
}

/// Default QoS admission-control policy for WeiPS parameter servers:
/// serving reads are the protected class, migration/checkpoint transfers
/// are capped bulk, training pushes and admin stay control. `bulk_cap`
/// of 0 resolves to half the handler pool (see [`crate::net::QosPolicy`]).
pub fn default_qos_policy(bulk_cap: usize) -> crate::net::QosPolicy {
    crate::net::QosPolicy {
        predict_methods: vec![methods::SPARSE_PULL, methods::DENSE_PULL, methods::PING],
        bulk_methods: vec![
            methods::MIGRATE_PULL,
            methods::MIGRATE_APPLY,
            methods::SAVE_CKPT,
            methods::LOAD_CKPT,
        ],
        bulk_inflight_max: bulk_cap,
        control_inflight_max: 0,
    }
}

pub use master::MasterShard;
pub use slave::{ServingTable, SlaveShard};
