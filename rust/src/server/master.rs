//! Master shard: the training-facing parameter server (§3.2).
//!
//! Holds the authoritative optimizer state, applies server-side updates on
//! every trainer push (scalar FTRL for small batches, the AOT Pallas
//! kernel for large blocks), feeds dirty ids to the sync [`Collector`],
//! and snapshots itself for cold-backup checkpoints. Fault tolerance is
//! checkpoint-based (§4.2.1) — the scheduler drives save/load.
//!
//! Sparse state lives in [`StripedSparseTable`]s: sparse pushes and pulls
//! take only the outer state lock in *read* mode plus the stripe locks
//! their ids hash to, so concurrent trainer pushes, serving pulls, expire
//! passes and gather snapshots on different stripes never serialize on a
//! single table lock. The outer `RwLock` is written only by dense updates
//! and whole-shard operations (restore / absorb / dense sync bookkeeping).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::codec::{Decode, Encode, Reader, Writer};
use crate::config::ModelSpec;
use crate::net::Service;
use crate::optim::BatchedFtrl;
use crate::proto::{
    Ack, CkptRequest, DensePull, DenseValues, SlotPull, SlotSeal, SparsePull, SparsePush,
    SparseValues,
};
use crate::runtime::Engine;
use crate::server::methods;
use crate::reshard::{SlotMap, SlotSet};
use crate::storage::{CheckpointStore, CkptKind, CkptManifest};
use crate::sync::collector::Collector;
use crate::sync::router::Router;
use crate::table::{
    aggregate_grads, DeltaRow, DenseOpt, DenseTable, SparseTable, StripedSparseTable,
};
use crate::util::clock::Clock;
use crate::{Error, Result};

/// Use the AOT Pallas FTRL kernel when a push touches at least this many
/// unique rows. The kernel executes fixed (ftrl_block_rows × dim) blocks,
/// so small pushes pay full-block padding; on CPU-interpret PJRT the
/// scalar loop wins below a full block (EXPERIMENTS.md §Perf — on a real
/// TPU the crossover is far lower; override with WEIPS_BATCHED_MIN_ROWS).
fn batched_ftrl_min_rows() -> usize {
    use std::sync::OnceLock;
    static MIN: OnceLock<usize> = OnceLock::new();
    *MIN.get_or_init(|| {
        std::env::var("WEIPS_BATCHED_MIN_ROWS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(8192)
    })
}

struct MasterState {
    sparse: Vec<StripedSparseTable>,
    dense: Vec<DenseTable>,
    /// Last dense version included in a gather flush, per dense table.
    dense_synced: Vec<u64>,
}

/// Counters exposed through `STATS`.
#[derive(Debug, Default)]
pub struct MasterMetrics {
    pub pulls: AtomicU64,
    pub pushes: AtomicU64,
    pub push_rows: AtomicU64,
    pub batched_kernel_rows: AtomicU64,
    pub scalar_rows: AtomicU64,
}

/// An encoded dirty-epoch delta chunk (everything mutated since a cut).
pub struct DeltaChunk {
    pub bytes: Vec<u8>,
    pub upserts: usize,
    pub deletes: usize,
}

/// One master shard.
pub struct MasterShard {
    pub shard_id: u32,
    pub spec: ModelSpec,
    state: RwLock<MasterState>,
    collector: Arc<Collector>,
    batched: Vec<Option<BatchedFtrl>>, // per sparse table, when usable
    clock: Arc<dyn Clock>,
    /// Downgrade freeze: pushes rejected while set (§4.3.2).
    frozen: AtomicBool,
    /// Shard-level checkpoint epoch counter; all sparse tables' write
    /// epochs move in lockstep with it (see [`Self::cut_epoch`]).
    ckpt_epoch: AtomicU64,
    /// Slot-route guard (elastic resharding): when installed, pushes for
    /// ids this shard does not own under the current slot map are NACKed
    /// with [`Error::StaleRoute`] *before* anything applies — a stale
    /// client re-splits by the bumped map and retries, so updates are
    /// never silently dropped or doubly applied. `None` (standalone
    /// shards, unit tests) costs nothing.
    route_guard: RwLock<Option<Router>>,
    /// Slots sealed for a live-migration hand-off. Pushes hold the read
    /// side across their apply, so [`Self::seal_slots`] (write side)
    /// returns only after every in-flight push has drained — the
    /// happens-before edge the final migration delta relies on.
    sealed_slots: RwLock<Option<SlotSet>>,
    /// Nanoseconds spent applying sparse pushes since the gather last
    /// drained it ([`Self::take_push_apply_ns`]) — the `push_apply` stage
    /// of the update-journey trace. Only accumulated while tracing is on.
    push_apply_ns: AtomicU64,
    pub metrics: MasterMetrics,
}

impl MasterShard {
    /// Build a shard for `spec` with the default stripe count
    /// ([`crate::table::default_stripe_count`]). `engine` enables the
    /// batched AOT FTRL path (pass `None` for pure-scalar operation, e.g.
    /// unit tests).
    pub fn new(
        shard_id: u32,
        spec: ModelSpec,
        engine: Option<Arc<Engine>>,
        entry_threshold: u32,
        clock: Arc<dyn Clock>,
    ) -> Result<MasterShard> {
        Self::with_stripes(
            shard_id,
            spec,
            engine,
            entry_threshold,
            crate::table::default_stripe_count(),
            clock,
        )
    }

    /// Build a shard with an explicit per-table lock-stripe count (the
    /// cluster config's `table_stripes` knob) and the default arena row
    /// store.
    pub fn with_stripes(
        shard_id: u32,
        spec: ModelSpec,
        engine: Option<Arc<Engine>>,
        entry_threshold: u32,
        stripes: usize,
        clock: Arc<dyn Clock>,
    ) -> Result<MasterShard> {
        Self::with_row_store(
            shard_id,
            spec,
            engine,
            entry_threshold,
            stripes,
            crate::table::RowStore::Arena,
            clock,
        )
    }

    /// [`Self::with_stripes`] with an explicit row-value backing (the
    /// cluster config's `table_row_store` knob).
    pub fn with_row_store(
        shard_id: u32,
        spec: ModelSpec,
        engine: Option<Arc<Engine>>,
        entry_threshold: u32,
        stripes: usize,
        row_store: crate::table::RowStore,
        clock: Arc<dyn Clock>,
    ) -> Result<MasterShard> {
        let mut sparse = Vec::new();
        let mut batched = Vec::new();
        for t in &spec.sparse {
            let opt = spec.optimizer_for(&t.name)?;
            sparse.push(StripedSparseTable::with_row_store(
                &t.name,
                t.dim,
                opt,
                entry_threshold,
                stripes,
                row_store,
            ));
            let b = match (&engine, t.optimizer.as_str()) {
                (Some(eng), "ftrl") => BatchedFtrl::new(eng.clone(), t.dim).ok(),
                _ => None,
            };
            batched.push(b);
        }
        let dense = spec
            .dense
            .iter()
            .map(|d| {
                DenseTable::new(&d.name, spec.dense_init(d), DenseOpt::Adagrad { lr: 0.05, eps: 1e-8 })
            })
            .collect::<Vec<_>>();
        let dense_synced = vec![u64::MAX; dense.len()];
        Ok(MasterShard {
            shard_id,
            spec,
            state: RwLock::new(MasterState { sparse, dense, dense_synced }),
            // Same stripe count as the tables: the collector's per-stripe
            // queues line up with the tables' lock stripes, so gather can
            // snapshot its groups without re-hashing.
            collector: Arc::new(Collector::with_stripes(stripes.max(1))),
            batched,
            clock,
            frozen: AtomicBool::new(false),
            ckpt_epoch: AtomicU64::new(1),
            route_guard: RwLock::new(None),
            sealed_slots: RwLock::new(None),
            push_apply_ns: AtomicU64::new(0),
            metrics: MasterMetrics::default(),
        })
    }

    /// Drain the accumulated push-apply nanoseconds (see
    /// `push_apply_ns`). Called by the gather when it attributes the
    /// `push_apply` trace stage to a sampled flush.
    pub fn take_push_apply_ns(&self) -> u64 {
        self.push_apply_ns.swap(0, Ordering::Relaxed)
    }

    /// The sync collector fed by this shard's pushes.
    pub fn collector(&self) -> Arc<Collector> {
        self.collector.clone()
    }

    /// Index of a sparse table in the spec order.
    pub fn table_index(&self, name: &str) -> Result<u16> {
        self.spec
            .sparse
            .iter()
            .position(|t| t.name == name)
            .map(|i| i as u16)
            .ok_or_else(|| Error::NotFound(format!("sparse table {name}")))
    }

    /// Freeze/unfreeze pushes (downgrade execution support).
    pub fn set_frozen(&self, frozen: bool) {
        self.frozen.store(frozen, Ordering::Release);
    }

    /// True while the shard rejects pushes.
    pub fn is_frozen(&self) -> bool {
        self.frozen.load(Ordering::Acquire)
    }

    /// Pull one slot (or full rows with `slot == "*"`). Missing ids read 0.
    /// Takes the state lock in read mode; contention is per stripe.
    ///
    /// Route-guarded like pushes: once a migration cutover re-owns an
    /// id, a pull still routed here by a stale map NACKs with
    /// [`Error::StaleRoute`] instead of silently reading zeros off the
    /// purged donor (the client re-splits and retries). The ownership
    /// check runs **after** the value read: the donor purge strictly
    /// follows the map install, so values read while still owned are
    /// live, and a read that could have raced the purge fails the
    /// post-read check and is discarded — no TOCTOU window. Sealed-but-
    /// owned slots still serve; their rows are live until the cutover.
    pub fn sparse_pull(&self, req: &SparsePull) -> Result<SparseValues> {
        self.metrics.pulls.fetch_add(1, Ordering::Relaxed);
        let idx = self.table_index(&req.table)? as usize;
        let now = self.clock.now_ms();
        let state = self.state.read().unwrap();
        let table = &state.sparse[idx];
        let out = if req.slot == "*" {
            let width = table.optimizer().row_width(table.dim());
            let mut values = vec![0.0f32; req.ids.len() * width];
            table.pull_rows(&req.ids, &mut values);
            SparseValues { width: width as u32, values }
        } else {
            let dim = table.dim();
            let mut values = vec![0.0f32; req.ids.len() * dim];
            table.pull_slot(&req.ids, &req.slot, now, &mut values)?;
            SparseValues { width: dim as u32, values }
        };
        drop(state);
        self.check_owned(&req.ids, "pull")?;
        if let Some(router) = self.route_guard.read().unwrap().as_ref() {
            router.record_pull_heat(&req.ids);
        }
        Ok(out)
    }

    /// NACK with [`Error::StaleRoute`] unless every id is owned by this
    /// shard under the guard's current slot map (no-op without a guard).
    /// Shared by the push gate and the post-read pull check.
    fn check_owned(&self, ids: &[u64], what: &str) -> Result<()> {
        let guard = self.route_guard.read().unwrap().clone();
        if let Some(router) = &guard {
            let map = router.snapshot();
            for &id in ids {
                let slot = map.slot_of(id);
                let owner = map.shard_of_slot(slot);
                if owner != self.shard_id {
                    return Err(Error::StaleRoute(format!(
                        "shard {}: {what} of id {id} (slot {slot}) owned by shard {owner} at \
                         routing epoch {}",
                        self.shard_id, map.epoch
                    )));
                }
            }
        }
        Ok(())
    }

    /// Apply a gradient push: aggregate duplicates, entry-filter, optimize
    /// (batched kernel when large), record dirty ids. Takes the state lock
    /// in read mode; per-stripe write locks serialize same-stripe ids only.
    pub fn sparse_push(&self, req: &SparsePush) -> Result<()> {
        if self.is_frozen() {
            return Err(Error::Unavailable("master frozen for version switch".into()));
        }
        self.metrics.pushes.fetch_add(1, Ordering::Relaxed);
        // Update-journey trace: one relaxed load + branch when tracing is
        // off; the apply time is attributed to the sampled batch that
        // eventually flushes this window (see `Gather`).
        let trace_start = crate::trace::enabled().then(crate::util::mono_ns);
        let idx = self.table_index(&req.table)? as usize;
        let now = self.clock.now_ms();
        // Slot-route gate, taken *before* the state lock (the one
        // ordering rule between the two: sealed → state, shared with the
        // expire path) and held in read mode across the whole apply, so
        // a migration seal (write side) is a barrier — once `seal_slots`
        // returns, no in-flight push can still be mutating the sealed
        // slots.
        let sealed = self.sealed_slots.read().unwrap();
        let state = self.state.read().unwrap();
        let table = &state.sparse[idx];
        let dim = table.dim();
        if req.grads.len() != req.ids.len() * dim {
            return Err(Error::Codec(format!(
                "push grads {} != ids {} * dim {dim}",
                req.grads.len(),
                req.ids.len()
            )));
        }
        let (uids, ugrads) = aggregate_grads(&req.ids, &req.grads, dim);

        // Rejection happens before anything applies, so a NACKed push
        // retried by the client is applied exactly once. The sealed gate
        // stands on its own (a remote `weips master` driven purely by
        // the SEAL_SLOTS RPC has no route guard) — it hashes against the
        // seal's own universe.
        if let Some(set) = sealed.as_ref() {
            for &id in &uids {
                let slot = crate::reshard::slot_of(id, set.universe());
                if set.contains(slot) {
                    return Err(Error::StaleRoute(format!(
                        "shard {}: slot {slot} sealed for migration hand-off",
                        self.shard_id
                    )));
                }
            }
        }
        self.check_owned(&uids, "push")?;
        if let Some(router) = self.route_guard.read().unwrap().as_ref() {
            router.record_push_heat(&uids);
        }
        self.metrics.push_rows.fetch_add(uids.len() as u64, Ordering::Relaxed);

        let touched: Vec<u64> = if let Some(kernel) = self.batched[idx].as_ref() {
            // Batched AOT path: per stripe — entry-filter, gather (z, n),
            // run the Pallas kernel, scatter (z, n, w) back, all under
            // that stripe's write lock. The scalar/kernel crossover is
            // applied per stripe *invocation* (the kernel pads each call
            // to a full block); undersized stripe groups go scalar.
            let mut touched = Vec::with_capacity(uids.len());
            let result = table.apply_batch_with(
                &uids,
                &ugrads,
                now,
                batched_ftrl_min_rows(),
                &mut touched,
                |g, z, n, w| kernel.update(g, z, n, w),
            );
            let kernel_rows = match result {
                Ok(k) => k,
                Err(e) => {
                    // Stripes committed before the kernel error stay
                    // applied; record them so slaves don't go stale, then
                    // surface the error.
                    drop(state);
                    self.collector.record_updates(idx as u16, &touched);
                    return Err(e);
                }
            };
            self.metrics.batched_kernel_rows.fetch_add(kernel_rows, Ordering::Relaxed);
            self.metrics
                .scalar_rows
                .fetch_add(touched.len() as u64 - kernel_rows, Ordering::Relaxed);
            touched
        } else {
            self.metrics.scalar_rows.fetch_add(uids.len() as u64, Ordering::Relaxed);
            table.apply_batch(&uids, &ugrads, now)
        };
        drop(state);
        self.collector.record_updates(idx as u16, &touched);
        if let Some(t0) = trace_start {
            self.push_apply_ns
                .fetch_add(crate::util::mono_ns().saturating_sub(t0), Ordering::Relaxed);
        }
        Ok(())
    }

    /// Read a dense table.
    pub fn dense_pull(&self, req: &DensePull) -> Result<DenseValues> {
        let state = self.state.read().unwrap();
        let t = state
            .dense
            .iter()
            .find(|d| d.name() == req.table)
            .ok_or_else(|| Error::NotFound(format!("dense table {}", req.table)))?;
        Ok(DenseValues {
            model: req.model.clone(),
            table: req.table.clone(),
            values: t.values().to_vec(),
        })
    }

    /// Apply a dense gradient.
    pub fn dense_push(&self, req: &DenseValues) -> Result<()> {
        if self.is_frozen() {
            return Err(Error::Unavailable("master frozen for version switch".into()));
        }
        let mut state = self.state.write().unwrap();
        let t = state
            .dense
            .iter_mut()
            .find(|d| d.name() == req.table)
            .ok_or_else(|| Error::NotFound(format!("dense table {}", req.table)))?;
        t.apply_grad(&req.values)
    }

    /// Run the feature-expire pass (§4.1c); evictions are recorded as sync
    /// deletes so slaves drop the rows too. Walks one stripe at a time, so
    /// pushes/pulls on other stripes keep flowing. Returns evicted count.
    pub fn expire_features(&self, ttl_ms: u64) -> usize {
        self.expire_features_pooled(ttl_ms, None)
    }

    /// [`Self::expire_features`] with the per-stripe scans fanned out over
    /// `pool` (the cluster's shared sync pool). Eviction recording stays
    /// in stripe order, so the sync-delete stream is identical to the
    /// sequential pass.
    pub fn expire_features_pooled(
        &self,
        ttl_ms: u64,
        pool: Option<&crate::util::ThreadPool>,
    ) -> usize {
        if ttl_ms == 0 {
            return 0;
        }
        // Hold the seal gate in read mode for the whole pass: an expire
        // racing a migration hand-off could evict a moved row *after* the
        // final delta and stream a delete that kills the recipient's live
        // copy downstream. Sealed windows are milliseconds; skip and let
        // the next control tick expire.
        let sealed = self.sealed_slots.read().unwrap();
        if sealed.is_some() {
            return 0;
        }
        let now = self.clock.now_ms();
        let state = self.state.read().unwrap();
        let mut total = 0;
        let mut evictions = Vec::new();
        for (idx, table) in state.sparse.iter().enumerate() {
            let dead = table.expire_pooled(now, ttl_ms, pool);
            total += dead.len();
            if !dead.is_empty() {
                evictions.push((idx as u16, dead));
            }
        }
        drop(state);
        for (idx, dead) in evictions {
            self.collector.record_deletes(idx, &dead);
        }
        total
    }

    /// Snapshot the full shard state (checkpoint payload).
    pub fn snapshot(&self) -> Vec<u8> {
        let state = self.state.read().unwrap();
        let mut w = Writer::with_capacity(1 << 16);
        w.put_u32(self.shard_id);
        w.put_varint(state.sparse.len() as u64);
        for t in &state.sparse {
            t.encode_rows(&mut w);
        }
        w.put_varint(state.dense.len() as u64);
        for d in &state.dense {
            d.encode(&mut w);
        }
        w.into_bytes()
    }

    /// Restore shard state from a snapshot produced by [`Self::snapshot`]
    /// — possibly taken by a *different* shard id in a differently-sized
    /// cluster (dynamic routing on load, §4.2.1d): rows not owned by this
    /// shard under `router` are skipped when a router is given.
    pub fn restore(
        &self,
        bytes: &[u8],
        router: Option<(&crate::sync::router::Router, u32)>,
    ) -> Result<()> {
        let mut r = Reader::new(bytes);
        let _src_shard = r.get_u32()?;
        let n_sparse = r.get_varint()? as usize;
        let mut state = self.state.write().unwrap();
        if n_sparse != state.sparse.len() {
            return Err(Error::Checkpoint(format!(
                "snapshot has {n_sparse} sparse tables, spec has {}",
                state.sparse.len()
            )));
        }
        for t in state.sparse.iter() {
            t.decode_rows(&mut r)?;
        }
        // Dynamic routing: drop rows that no longer belong to this shard
        // (one map snapshot for the whole pass — per-id routes must not
        // straddle a concurrent slot-map install).
        if let Some((router, my_shard)) = router {
            let map = router.snapshot();
            for t in state.sparse.iter() {
                let foreign: Vec<u64> =
                    t.ids().into_iter().filter(|id| map.shard_of(*id) != my_shard).collect();
                for id in foreign {
                    t.delete(id);
                }
            }
        }
        let n_dense = r.get_varint()? as usize;
        if n_dense != state.dense.len() {
            return Err(Error::Checkpoint(format!(
                "snapshot has {n_dense} dense tables, spec has {}",
                state.dense.len()
            )));
        }
        for d in state.dense.iter_mut() {
            d.decode_into(&mut r)?;
        }
        Ok(())
    }

    // -- incremental durability (dirty epochs, delta chunks, chains) ----------

    /// Current write epoch: the value every mutation stamps its rows with.
    pub fn write_epoch(&self) -> u64 {
        self.ckpt_epoch.load(Ordering::SeqCst)
    }

    /// Seal the current epoch window. Returns the cut — every mutation
    /// applied so far is stamped `<= cut` — and moves all sparse tables
    /// to `cut + 1`, so later mutations belong to the next window. A
    /// delta collected afterwards with `since = previous cut` captures
    /// exactly the sealed window (plus any raced `cut + 1` stragglers,
    /// which the next window re-captures — duplicates, never losses).
    pub fn cut_epoch(&self) -> u64 {
        let cut = self.ckpt_epoch.fetch_add(1, Ordering::SeqCst);
        let state = self.state.read().unwrap();
        for t in &state.sparse {
            t.set_write_epoch(cut + 1);
        }
        cut
    }

    /// Re-arm the write epoch (after restoring a checkpoint whose
    /// manifest recorded `epoch - 1` for this shard): future mutations
    /// stamp `epoch`, so the next delta against that manifest sees them.
    pub fn set_write_epoch(&self, epoch: u64) {
        self.ckpt_epoch.store(epoch, Ordering::SeqCst);
        let state = self.state.read().unwrap();
        for t in &state.sparse {
            t.set_write_epoch(epoch);
        }
    }

    // -- elastic resharding (slot routing, live migration) ---------------------

    /// Install the master cluster's shared router as this shard's
    /// slot-route guard: pushes for ids the current slot map assigns
    /// elsewhere NACK with [`Error::StaleRoute`].
    pub fn set_route_guard(&self, router: Router) {
        *self.route_guard.write().unwrap() = Some(router);
    }

    /// Current routing epoch seen by the guard (0 when no guard).
    pub fn route_epoch(&self) -> u64 {
        self.route_guard.read().unwrap().as_ref().map(|r| r.epoch()).unwrap_or(0)
    }

    /// Install a bumped slot map into the guard's shared cell (remote
    /// cutover RPC). Errors without a guard or on a stale epoch.
    pub fn install_slot_map(&self, map: SlotMap) -> Result<()> {
        match self.route_guard.read().unwrap().as_ref() {
            Some(router) => {
                router.install(map)?;
                Ok(())
            }
            None => Err(Error::State("no route guard installed".into())),
        }
    }

    /// Published slot map, encoded for the wire: fresh slaves and remote
    /// trainers bootstrap their routers from it instead of assuming the
    /// seed layout, and clients re-fetch it on [`Error::StaleRoute`].
    /// Errors when no route guard is installed — a guard-less shard has
    /// no authoritative map to publish.
    pub fn slot_map_bytes(&self) -> Result<Vec<u8>> {
        match self.route_guard.read().unwrap().as_ref() {
            Some(router) => Ok(router.snapshot().to_bytes()),
            None => Err(Error::State("no route guard installed".into())),
        }
    }

    /// Validate a caller-supplied slot universe: it must fit the u16
    /// slot space (larger values would alias through `slot_of`'s modulo
    /// and select the wrong rows — on a purge, unrecoverably) and, when
    /// a route guard is installed, match the guard's map (a mismatched
    /// universe would filter rows by a *different* slot hash — silent
    /// corruption, not an error). Guard-less shards accept any in-range
    /// universe: the orchestrator is then the single source of truth.
    pub fn check_universe(&self, universe: usize) -> Result<()> {
        if universe == 0 || universe > u16::MAX as usize + 1 {
            return Err(Error::Routing(format!(
                "shard {}: slot universe {universe} out of range",
                self.shard_id
            )));
        }
        if let Some(router) = self.route_guard.read().unwrap().as_ref() {
            let slots = router.snapshot().slots();
            if slots != universe {
                return Err(Error::Routing(format!(
                    "shard {}: slot universe {universe} != routed {slots}",
                    self.shard_id
                )));
            }
        }
        Ok(())
    }

    /// Seal `slots` for a migration hand-off: returns only after every
    /// in-flight push has drained (pushes hold the read side across their
    /// apply); afterwards pushes touching the slots NACK until the map
    /// cutover re-routes them. Rejected while another seal is active —
    /// overwriting would silently lift a concurrent migration's barrier
    /// (one hand-off per donor at a time).
    pub fn seal_slots(&self, slots: SlotSet) -> Result<()> {
        let mut sealed = self.sealed_slots.write().unwrap();
        if sealed.is_some() {
            return Err(Error::State(format!(
                "shard {}: a migration hand-off is already sealed",
                self.shard_id
            )));
        }
        *sealed = Some(slots);
        Ok(())
    }

    /// Lift the migration seal.
    pub fn unseal_slots(&self) {
        *self.sealed_slots.write().unwrap() = None;
    }

    /// Encode everything in `slots` mutated since `since` (`None` = every
    /// row regardless of epoch — the migration base pass) as a slot
    /// chunk: header carrying the slot set, then per-table sections in
    /// the delta wire shape; no dense tail (dense state is replicated, it
    /// does not migrate). Collection holds one stripe *read* lock at a
    /// time — the donor keeps training.
    pub fn encode_slot_chunk(&self, since: Option<u64>, slots: &SlotSet) -> DeltaChunk {
        let state = self.state.read().unwrap();
        let mut w = Writer::with_capacity(1 << 12);
        w.put_u32(self.shard_id);
        w.put_varint(match since {
            None => 0,
            Some(cut) => cut + 1,
        });
        // The slot set travels with the chunk so the recipient can clear
        // orphans (below) without out-of-band coordination.
        w.put_varint(slots.universe() as u64);
        let members = slots.slots();
        w.put_varint(members.len() as u64);
        for s in &members {
            w.put_varint(*s as u64);
        }
        w.put_varint(state.sparse.len() as u64);
        let mut upserts = 0;
        let mut deletes = 0;
        for t in &state.sparse {
            let (u, d) = t.encode_slot_delta_rows(since, slots, &mut w);
            upserts += u;
            deletes += d;
        }
        DeltaChunk { bytes: w.into_bytes(), upserts, deletes }
    }

    /// Apply a slot chunk on the migration recipient. Rows land stamped
    /// with each table's *current* write epoch (dirty), so the next WAL
    /// journal tick or delta checkpoint seals the new ownership — the
    /// coordinator establishes that durability *before* releasing the
    /// donor, closing the crash window. A **base** chunk (`since = 0`)
    /// first purges the recipient's copy of the slots: a retry after an
    /// aborted earlier attempt must not resurrect rows the donor deleted
    /// in between. Returns (rows upserted, deleted).
    pub fn apply_slot_chunk(&self, bytes: &[u8]) -> Result<(usize, usize)> {
        let mut r = Reader::new(bytes);
        let _src_shard = r.get_u32()?;
        let since = r.get_varint()?;
        let universe = r.get_varint()? as usize;
        if universe == 0 || universe > u16::MAX as usize + 1 {
            return Err(Error::Checkpoint(format!("slot chunk universe {universe} invalid")));
        }
        // Same gate as the other migration RPCs: a chunk hashed over a
        // different universe would purge/apply the wrong id set.
        self.check_universe(universe)?;
        let members = crate::proto::read_slot_list(&mut r)?;
        let set = SlotSet::from_slots(&members, universe)?;
        let n_sparse = r.get_varint()? as usize;
        let state = self.state.read().unwrap();
        if n_sparse != state.sparse.len() {
            return Err(Error::Checkpoint(format!(
                "slot chunk has {n_sparse} sparse tables, spec has {}",
                state.sparse.len()
            )));
        }
        if since == 0 {
            for t in state.sparse.iter() {
                t.purge_slots(&set);
            }
        }
        let mut upserts = 0;
        let mut deletes = 0;
        for t in state.sparse.iter() {
            let stamp = t.write_epoch();
            let (u, d) = t.decode_delta_rows(&mut r, stamp)?;
            upserts += u;
            deletes += d;
        }
        Ok((upserts, deletes))
    }

    /// Slot-filtered row collection per table (`None` = all rows) —
    /// migration sizing and the byte-identity drills.
    pub fn collect_slot_delta(
        &self,
        since: Option<u64>,
        slots: &SlotSet,
    ) -> Vec<(String, Vec<DeltaRow>, Vec<u64>)> {
        let state = self.state.read().unwrap();
        state
            .sparse
            .iter()
            .map(|t| {
                let (up, del) = t.collect_slot_delta(since, slots);
                (t.name().to_string(), up, del)
            })
            .collect()
    }

    /// Silently drop every row in `slots` across sparse tables — no
    /// tombstones, no dirty stamps, no sync deletes (the migration
    /// recipient's lineage owns the rows; a donor-side delete record
    /// would wrongly evict them downstream). Returns rows removed.
    pub fn purge_slots(&self, slots: &SlotSet) -> usize {
        let state = self.state.read().unwrap();
        state.sparse.iter().map(|t| t.purge_slots(slots)).sum()
    }

    /// Drop rows the current slot map assigns to other shards (post-
    /// recovery hygiene: a restored chain predates slot moves).
    pub fn purge_foreign_rows(&self, map: &SlotMap) -> usize {
        let mut foreign = SlotSet::empty(map.slots());
        for slot in (0..map.slots()).map(|s| s as u16) {
            if map.shard_of_slot(slot) != self.shard_id {
                foreign.insert(slot);
            }
        }
        if foreign.is_empty() {
            return 0;
        }
        self.purge_slots(&foreign)
    }

    /// Enable/disable tombstone tracking on every sparse table. Off for
    /// deployments with no incremental checkpoint consumer (full mode,
    /// scheduler-less serving), so expired rows free all their memory.
    pub fn set_incremental_tracking(&self, on: bool) {
        let state = self.state.read().unwrap();
        for t in &state.sparse {
            t.set_grave_tracking(on);
        }
    }

    /// Dense-table version counters (the WAL journal's change gate).
    pub fn dense_versions(&self) -> Vec<u64> {
        let state = self.state.read().unwrap();
        state.dense.iter().map(|d| d.version).collect()
    }

    /// (dirty rows, tombstones) across sparse tables since `since`.
    pub fn dirty_counts(&self, since: u64) -> (usize, usize) {
        let state = self.state.read().unwrap();
        let mut rows = 0;
        let mut graves = 0;
        for t in &state.sparse {
            let (r, g) = t.dirty_counts(since);
            rows += r;
            graves += g;
        }
        (rows, graves)
    }

    /// Split dirty census across sparse tables since `since`:
    /// (value-dirty rows, tombstones, access-only rows). The WAL journal
    /// uses it to pick between a full delta record and a metadata-only
    /// access-stamp record.
    pub fn dirty_counts_split(&self, since: u64) -> (usize, usize, usize) {
        let state = self.state.read().unwrap();
        let mut rows = 0;
        let mut graves = 0;
        let mut access = 0;
        for t in &state.sparse {
            let (r, g, a) = t.dirty_counts_split(since);
            rows += r;
            graves += g;
            access += a;
        }
        (rows, graves, access)
    }

    /// Encode a metadata-only micro-delta: per sparse table, the
    /// `(id, last_access_ms)` stamps of rows whose only dirt since
    /// `since` is an access-time refresh. Orders of magnitude smaller
    /// than a full delta for read-heavy windows, and enough to keep
    /// feature-expiry fidelity across recovery.
    pub fn encode_access_delta(&self, since: u64) -> Vec<u8> {
        let state = self.state.read().unwrap();
        let mut w = Writer::with_capacity(1 << 8);
        w.put_u32(self.shard_id);
        w.put_varint(since);
        w.put_varint(state.sparse.len() as u64);
        for t in &state.sparse {
            let stamps = t.collect_access_stamps(since);
            w.put_str(t.name());
            w.put_varint(stamps.len() as u64);
            for (id, last_access_ms) in stamps {
                w.put_varint(id);
                w.put_varint(last_access_ms);
            }
        }
        w.into_bytes()
    }

    /// Apply a metadata-only micro-delta written by
    /// [`Self::encode_access_delta`] (WAL replay). Unknown table names
    /// and ids without rows are skipped — the record is advisory
    /// metadata and hostile or stale payloads must degrade to a no-op,
    /// never a panic. Returns rows refreshed.
    pub fn apply_access_delta(&self, bytes: &[u8]) -> Result<usize> {
        let mut r = Reader::new(bytes);
        let _src_shard = r.get_u32()?;
        let _since = r.get_varint()?;
        let n_tables = r.get_varint()? as usize;
        let state = self.state.read().unwrap();
        if n_tables > crate::storage::incremental::MAX_CHAIN {
            return Err(Error::Checkpoint(format!(
                "access delta claims {n_tables} tables"
            )));
        }
        let mut refreshed = 0usize;
        for _ in 0..n_tables {
            let name = r.get_str()?;
            let count = r.get_varint()? as usize;
            let mut stamps = Vec::with_capacity(count.min(1 << 16));
            for _ in 0..count {
                let id = r.get_varint()?;
                let last_access_ms = r.get_varint()?;
                stamps.push((id, last_access_ms));
            }
            if let Some(t) = state.sparse.iter().find(|t| t.name() == name) {
                refreshed += t.apply_access_stamps(&stamps);
            }
        }
        Ok(refreshed)
    }

    /// Drop tombstones sealed through `through` (call after the
    /// checkpoint that recorded that cut — no future delta can need them).
    pub fn prune_dirty(&self, through: u64) {
        let state = self.state.read().unwrap();
        for t in &state.sparse {
            t.prune_graves(through);
        }
    }

    /// Encode a delta chunk: every sparse row mutated since epoch
    /// `since` (with metadata — restores are byte-identical), tombstones
    /// for rows deleted since, and the full dense state. Collection
    /// walks one stripe at a time under that stripe's *read* lock, so a
    /// checkpoint never globally stalls training. Holds the outer state
    /// lock in read mode only.
    pub fn encode_delta(&self, since: u64) -> DeltaChunk {
        let state = self.state.read().unwrap();
        let mut w = Writer::with_capacity(1 << 12);
        w.put_u32(self.shard_id);
        w.put_varint(since);
        w.put_varint(state.sparse.len() as u64);
        let mut upserts = 0;
        let mut deletes = 0;
        for t in &state.sparse {
            let (u, d) = t.encode_delta_rows(since, &mut w);
            upserts += u;
            deletes += d;
        }
        w.put_varint(state.dense.len() as u64);
        for d in &state.dense {
            d.encode(&mut w);
        }
        DeltaChunk { bytes: w.into_bytes(), upserts, deletes }
    }

    /// Apply a delta chunk produced by [`Self::encode_delta`].
    /// `mark_dirty = false` for chain restores (the chunk's checkpoint
    /// already covers these rows), `true` for WAL replay (the replayed
    /// rows must be captured by the *next* delta). Returns
    /// (rows upserted, rows deleted).
    pub fn apply_delta(&self, bytes: &[u8], mark_dirty: bool) -> Result<(usize, usize)> {
        let mut r = Reader::new(bytes);
        let _src_shard = r.get_u32()?;
        let _since = r.get_varint()?;
        let n_sparse = r.get_varint()? as usize;
        let mut state = self.state.write().unwrap();
        if n_sparse != state.sparse.len() {
            return Err(Error::Checkpoint(format!(
                "delta has {n_sparse} sparse tables, spec has {}",
                state.sparse.len()
            )));
        }
        let mut upserts = 0;
        let mut deletes = 0;
        for t in state.sparse.iter() {
            let stamp = if mark_dirty { t.write_epoch() } else { 0 };
            let (u, d) = t.decode_delta_rows(&mut r, stamp)?;
            upserts += u;
            deletes += d;
        }
        let n_dense = r.get_varint()? as usize;
        if n_dense != state.dense.len() {
            return Err(Error::Checkpoint(format!(
                "delta has {n_dense} dense tables, spec has {}",
                state.dense.len()
            )));
        }
        for d in state.dense.iter_mut() {
            d.decode_into(&mut r)?;
        }
        Ok((upserts, deletes))
    }

    /// Restore this shard from the incremental chain ending at `version`:
    /// base snapshot, then each delta chunk in order, then re-arm the
    /// write epoch from the tip manifest so post-recovery mutations land
    /// in the next delta. `manifest_slot` is this shard's position in the
    /// manifest's save order (== shard id for whole-cluster
    /// orchestrators, 0 for a standalone single-shard store). Returns the
    /// tip manifest — its `wal_offsets` / `queue_offsets` tell the caller
    /// where tail replay starts.
    pub fn restore_chain(
        &self,
        store: &CheckpointStore,
        version: u64,
        manifest_slot: usize,
    ) -> Result<CkptManifest> {
        let chain = crate::storage::incremental::resolve_chain(store, &self.spec.name, version)?;
        for m in &chain {
            let bytes = store.load_chunk(&self.spec.name, m.version, self.shard_id, m.kind)?;
            match m.kind {
                CkptKind::Base => self.restore(&bytes, None)?,
                CkptKind::Delta => {
                    self.apply_delta(&bytes, false)?;
                }
            }
        }
        let tip = chain.into_iter().next_back().expect("resolve_chain returns >= 1 link");
        let epoch = tip.epochs.get(manifest_slot).copied().unwrap_or(0);
        self.set_write_epoch(epoch + 1);
        Ok(tip)
    }

    /// Merge rows from another shard's snapshot into this shard, keeping
    /// only rows this shard owns (cluster migration / resharding path).
    pub fn absorb(
        &self,
        bytes: &[u8],
        router: &crate::sync::router::Router,
        my_shard: u32,
    ) -> Result<usize> {
        let mut r = Reader::new(bytes);
        let _src_shard = r.get_u32()?;
        let n_sparse = r.get_varint()? as usize;
        let mut state = self.state.write().unwrap();
        if n_sparse != state.sparse.len() {
            return Err(Error::Checkpoint("table count mismatch".into()));
        }
        let now = self.clock.now_ms();
        let map = router.snapshot();
        let mut absorbed = 0;
        for t in state.sparse.iter() {
            // Decode into a scratch table, then filter-copy.
            let mut scratch = SparseTable::new(t.name(), t.dim(), t.optimizer().clone(), 1);
            scratch.decode_rows(&mut r)?;
            for (id, row) in scratch.iter() {
                if map.shard_of(*id) == my_shard {
                    t.upsert_row(*id, &row.values, now)?;
                    absorbed += 1;
                }
            }
        }
        // Dense tables: take the source values verbatim (replicated state).
        let n_dense = r.get_varint()? as usize;
        if n_dense != state.dense.len() {
            return Err(Error::Checkpoint("dense count mismatch".into()));
        }
        for d in state.dense.iter_mut() {
            d.decode_into(&mut r)?;
        }
        Ok(absorbed)
    }

    /// Replay a sync batch into this master's tables (partial-recovery
    /// path, §4.2.1b: the external queue as real-time incremental backup).
    /// Upserts carry full master rows, so applying them after a checkpoint
    /// restore reconstructs every post-checkpoint update.
    pub fn replay_sync_batch(&self, batch: &crate::proto::SyncBatch) -> Result<()> {
        self.replay_sync_batches(std::slice::from_ref(batch))
    }

    /// Replay a run of sync batches, coalesced: rows are grouped per
    /// table × lock stripe across the whole run first (in batch order, so
    /// later batches win), then applied through
    /// [`crate::table::StripedSparseTable::apply_grouped`] — one stripe
    /// lock acquisition per busy stripe per run instead of one per row
    /// per batch, which is what keeps post-downgrade queue replay bounded
    /// by row volume rather than batch count.
    pub fn replay_sync_batches(&self, batches: &[crate::proto::SyncBatch]) -> Result<()> {
        if batches.is_empty() {
            return Ok(());
        }
        let now = self.clock.now_ms();
        let state = self.state.read().unwrap();
        let mut per_table: Vec<Option<Vec<crate::table::RowOps<'_>>>> =
            (0..state.sparse.len()).map(|_| None).collect();
        for batch in batches {
            let idx = self.table_index(&batch.table)? as usize;
            let table = &state.sparse[idx];
            let groups = per_table[idx]
                .get_or_insert_with(|| (0..table.stripe_count()).map(|_| Vec::new()).collect());
            for entry in &batch.entries {
                let op = match &entry.op {
                    crate::proto::SyncOp::Upsert(values) => Some(values.as_slice()),
                    crate::proto::SyncOp::Delete => None,
                };
                groups[table.stripe_of(entry.id)].push((entry.id, op));
            }
        }
        for (idx, groups) in per_table.into_iter().enumerate() {
            if let Some(groups) = groups {
                state.sparse[idx].apply_grouped(&groups, now)?;
            }
        }
        Ok(())
    }

    /// Failure injection for E5: inflate + sign-flip every serving weight
    /// (the "abnormal change" the domino downgrade must detect). Test/bench
    /// only; goes through the normal collector so the corruption streams
    /// to the slaves like any update.
    pub fn corrupt_for_test(&self, scale: f32) -> Result<()> {
        let mut dirty: Vec<(u16, Vec<u64>)> = Vec::new();
        {
            let state = self.state.read().unwrap();
            for (idx, table) in state.sparse.iter().enumerate() {
                let dim = table.dim();
                let opt = table.optimizer().clone();
                let w_slot = opt
                    .slot_index("w")
                    .ok_or_else(|| Error::State("optimizer lacks w slot".into()))?;
                // Corrupt the z accumulator too (when present): FTRL
                // re-derives w from (z, n) on the next update, so w-only
                // corruption would self-heal for hot ids.
                let z_slot = opt.slot_index("z");
                let ids: Vec<u64> = table.ids();
                for id in &ids {
                    // A concurrent expire pass may evict between ids() and
                    // here (both run under the outer read lock now).
                    let Some(row) = table.get_row(*id) else { continue };
                    let mut values = row.values.to_vec();
                    for v in &mut values[w_slot * dim..(w_slot + 1) * dim] {
                        *v = -*v * scale - 0.5;
                    }
                    if let Some(z) = z_slot {
                        for v in &mut values[z * dim..(z + 1) * dim] {
                            *v = -*v * scale - 2.0;
                        }
                    }
                    table.upsert_row(*id, &values, 0)?;
                }
                dirty.push((idx as u16, ids));
            }
        }
        for (idx, ids) in dirty {
            self.collector.record_updates(idx, &ids);
        }
        Ok(())
    }

    /// Read current full rows + bump nothing (gather's value snapshot).
    /// Ids are grouped by stripe internally, each stripe read-locked once,
    /// so a snapshot concurrent with `apply_batch` on other stripes never
    /// blocks.
    pub fn read_rows_for_sync(&self, table: u16, ids: &[u64]) -> crate::table::RowSnapshot {
        let state = self.state.read().unwrap();
        state.sparse[table as usize].read_rows(ids)
    }

    /// Value snapshot for ids already grouped by lock stripe (the striped
    /// collector's layout). Per-stripe reads run concurrently on `pool`
    /// when given, each task holding only its stripe's read lock. Falls
    /// back to a flat snapshot if the group count does not match the
    /// table's stripes (e.g. a collector built with a different knob).
    pub fn read_rows_for_sync_grouped(
        &self,
        table: u16,
        groups: &[Vec<u64>],
        pool: Option<&crate::util::ThreadPool>,
    ) -> Vec<crate::table::RowSnapshot> {
        let state = self.state.read().unwrap();
        let t = &state.sparse[table as usize];
        if groups.len() != t.stripe_count() {
            let flat: Vec<u64> = groups.iter().flatten().copied().collect();
            return vec![t.read_rows(&flat)];
        }
        t.read_rows_grouped(groups, pool)
    }

    /// Dense tables whose version advanced since the last sync flush;
    /// marks them synced. Returns (dense index, name, values).
    pub fn dense_changed_since_sync(&self) -> Vec<(usize, String, Vec<f32>)> {
        let mut state = self.state.write().unwrap();
        let mut out = Vec::new();
        for i in 0..state.dense.len() {
            let v = state.dense[i].version;
            if state.dense_synced[i] != v {
                state.dense_synced[i] = v;
                out.push((i, state.dense[i].name().to_string(), state.dense[i].values().to_vec()));
            }
        }
        out
    }

    /// Total materialized rows across sparse tables.
    pub fn total_rows(&self) -> usize {
        let state = self.state.read().unwrap();
        state.sparse.iter().map(|t| t.len()).sum()
    }

    /// Materialized rows per sparse table, in spec order.
    pub fn table_rows(&self) -> Vec<(String, usize)> {
        let state = self.state.read().unwrap();
        self.spec
            .sparse
            .iter()
            .zip(&state.sparse)
            .map(|(spec, t)| (spec.name.clone(), t.len()))
            .collect()
    }

    /// Register this shard's observability series (request counters, row
    /// gauges) under `role`/`shard` — and a per-`table` row gauge, the
    /// registry's table-granularity series. Samplers hold a `Weak`, so a
    /// dropped shard's series disappear from scrapes; re-registering the
    /// same shard id replaces the previous entry.
    pub fn register_metrics(self: &Arc<Self>, role: &str) {
        use crate::metrics::register_fn;
        let labels =
            [("role", role.to_string()), ("shard", self.shard_id.to_string())];
        let counters: [(&'static str, fn(&MasterMetrics) -> &AtomicU64); 3] = [
            ("weips_master_pulls_total", |m| &m.pulls),
            ("weips_master_pushes_total", |m| &m.pushes),
            ("weips_master_push_rows_total", |m| &m.push_rows),
        ];
        for (name, get) in counters {
            let weak = Arc::downgrade(self);
            register_fn(
                name,
                &labels,
                Box::new(move || {
                    weak.upgrade().map(|s| get(&s.metrics).load(Ordering::Relaxed) as f64)
                }),
            );
        }
        let weak = Arc::downgrade(self);
        register_fn(
            "weips_master_rows",
            &labels,
            Box::new(move || weak.upgrade().map(|s| s.total_rows() as f64)),
        );
        for table in self.spec.sparse.iter().map(|t| t.name.clone()) {
            let weak = Arc::downgrade(self);
            let tname = table.clone();
            register_fn(
                "weips_master_table_rows",
                &[
                    ("role", role.to_string()),
                    ("shard", self.shard_id.to_string()),
                    ("table", table),
                ],
                Box::new(move || {
                    let s = weak.upgrade()?;
                    let rows =
                        s.table_rows().into_iter().find(|(n, _)| *n == tname)?.1;
                    Some(rows as f64)
                }),
            );
        }
        // Engaged row-store backing as an info-style gauge (value 1, the
        // backing in the `store` label): the degradation story needs the
        // *engaged* mode scrapeable, not just the configured knob.
        let store = {
            let state = self.state.read().unwrap();
            state.sparse.first().map(|t| t.row_store().name())
        };
        if let Some(store) = store {
            let weak = Arc::downgrade(self);
            register_fn(
                "weips_table_row_store_info",
                &[
                    ("role", role.to_string()),
                    ("shard", self.shard_id.to_string()),
                    ("store", store.to_string()),
                ],
                Box::new(move || weak.upgrade().map(|_| 1.0)),
            );
        }
    }

    /// Save this shard into `store` as `version`.
    pub fn save_checkpoint(&self, store: &CheckpointStore, version: u64) -> Result<()> {
        store.save_shard(&self.spec.name, version, self.shard_id, &self.snapshot())
    }

    /// Load this shard from `store` at `version` (same topology).
    pub fn load_checkpoint(&self, store: &CheckpointStore, version: u64) -> Result<()> {
        let bytes = store.load_shard(&self.spec.name, version, self.shard_id)?;
        self.restore(&bytes, None)
    }

    fn stats_json(&self) -> String {
        format!(
            r#"{{"shard":{},"rows":{},"pulls":{},"pushes":{},"push_rows":{},"batched_rows":{},"scalar_rows":{},"frozen":{}}}"#,
            self.shard_id,
            self.total_rows(),
            self.metrics.pulls.load(Ordering::Relaxed),
            self.metrics.pushes.load(Ordering::Relaxed),
            self.metrics.push_rows.load(Ordering::Relaxed),
            self.metrics.batched_kernel_rows.load(Ordering::Relaxed),
            self.metrics.scalar_rows.load(Ordering::Relaxed),
            self.is_frozen(),
        )
    }
}

/// RPC facade for a master shard (optionally checkpoint-capable).
pub struct MasterService {
    pub shard: Arc<MasterShard>,
    pub store: Option<Arc<CheckpointStore>>,
}

impl Service for MasterService {
    fn call(&self, method: u16, payload: &[u8]) -> Result<Vec<u8>> {
        match method {
            methods::SPARSE_PULL => {
                let req = SparsePull::from_bytes(payload)?;
                Ok(self.shard.sparse_pull(&req)?.to_bytes())
            }
            methods::SPARSE_PUSH => {
                let req = SparsePush::from_bytes(payload)?;
                self.shard.sparse_push(&req)?;
                Ok(Ack::ok().to_bytes())
            }
            methods::DENSE_PULL => {
                let req = DensePull::from_bytes(payload)?;
                Ok(self.shard.dense_pull(&req)?.to_bytes())
            }
            methods::DENSE_PUSH => {
                let req = DenseValues::from_bytes(payload)?;
                self.shard.dense_push(&req)?;
                Ok(Ack::ok().to_bytes())
            }
            methods::SAVE_CKPT => {
                let req = CkptRequest::from_bytes(payload)?;
                let store = self
                    .store
                    .as_ref()
                    .ok_or_else(|| Error::State("no checkpoint store attached".into()))?;
                self.shard.save_checkpoint(store, req.version)?;
                Ok(Ack::ok().to_bytes())
            }
            methods::LOAD_CKPT => {
                let req = CkptRequest::from_bytes(payload)?;
                let store = self
                    .store
                    .as_ref()
                    .ok_or_else(|| Error::State("no checkpoint store attached".into()))?;
                self.shard.load_checkpoint(store, req.version)?;
                Ok(Ack::ok().to_bytes())
            }
            methods::STATS => Ok(self.shard.stats_json().into_bytes()),
            methods::PING => Ok(Ack::ok().to_bytes()),
            methods::MIGRATE_PULL => {
                let req = SlotPull::from_bytes(payload)?;
                self.shard.check_universe(req.universe as usize)?;
                let set = SlotSet::from_slots(&req.slots, req.universe as usize)?;
                let since = if req.since == 0 { None } else { Some(req.since - 1) };
                Ok(self.shard.encode_slot_chunk(since, &set).bytes)
            }
            methods::MIGRATE_APPLY => {
                self.shard.apply_slot_chunk(payload)?;
                Ok(Ack::ok().to_bytes())
            }
            methods::SEAL_SLOTS => {
                let req = SlotSeal::from_bytes(payload)?;
                self.shard.check_universe(req.universe as usize)?;
                if req.slots.is_empty() {
                    self.shard.unseal_slots();
                } else {
                    self.shard
                        .seal_slots(SlotSet::from_slots(&req.slots, req.universe as usize)?)?;
                }
                Ok(Ack::ok().to_bytes())
            }
            methods::RELEASE_SLOTS => {
                // The remote release stage: purge the moved slots
                // silently and lift the seal — call only after the new
                // slot map is installed everywhere.
                let req = SlotSeal::from_bytes(payload)?;
                self.shard.check_universe(req.universe as usize)?;
                let set = SlotSet::from_slots(&req.slots, req.universe as usize)?;
                self.shard.purge_slots(&set);
                self.shard.unseal_slots();
                Ok(Ack::ok().to_bytes())
            }
            methods::ROUTE_EPOCH => Ok(self.shard.route_epoch().to_le_bytes().to_vec()),
            methods::FETCH_SLOT_MAP => self.shard.slot_map_bytes(),
            methods::INSTALL_SLOT_MAP => {
                let map = SlotMap::from_bytes(payload)?;
                self.shard.install_slot_map(map)?;
                Ok(Ack::ok().to_bytes())
            }
            m => Err(Error::Rpc(format!("master: unknown method {m}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelKind, ModelSpec};
    use crate::runtime::ModelConfig;
    use crate::util::clock::ManualClock;

    fn spec(kind: ModelKind) -> ModelSpec {
        let cfg = ModelConfig {
            batch_train: 8,
            batch_predict: 2,
            fields: 4,
            dim: 2,
            hidden: 8,
            ftrl_block_rows: 64,
            ftrl_alpha: 0.05,
            ftrl_beta: 1.0,
            ftrl_l1: 1.0,
            ftrl_l2: 1.0,
        };
        ModelSpec::derive("ctr", kind, &cfg)
    }

    fn shard(kind: ModelKind) -> (Arc<MasterShard>, ManualClock) {
        let clock = ManualClock::new(0);
        let m = MasterShard::new(0, spec(kind), None, 1, Arc::new(clock.clone())).unwrap();
        (Arc::new(m), clock)
    }

    fn push(m: &MasterShard, table: &str, ids: Vec<u64>, grads: Vec<f32>) {
        m.sparse_push(&SparsePush { model: "ctr".into(), table: table.into(), ids, grads })
            .unwrap();
    }

    fn pull(m: &MasterShard, table: &str, ids: Vec<u64>, slot: &str) -> SparseValues {
        m.sparse_pull(&SparsePull { model: "ctr".into(), table: table.into(), ids, slot: slot.into() })
            .unwrap()
    }

    #[test]
    fn push_pull_lifecycle() {
        let (m, _) = shard(ModelKind::Fm);
        push(&m, "w", vec![1, 2], vec![1.0, -1.0]);
        let w = pull(&m, "w", vec![1, 2, 3], "w");
        assert_eq!(w.width, 1);
        assert_eq!(w.values.len(), 3);
        assert_eq!(w.values[2], 0.0); // missing id
        // FTRL with |z|=1 <= l1 keeps w at 0 after one unit gradient; check z.
        let z = pull(&m, "w", vec![1, 2], "z");
        assert_eq!(z.values, vec![1.0, -1.0]);
        // Full-row pull.
        let full = pull(&m, "w", vec![1], "*");
        assert_eq!(full.width, 3);
        assert_eq!(full.values[0], 1.0);
    }

    #[test]
    fn push_validates_and_collects() {
        let (m, _) = shard(ModelKind::Fm);
        // Bad width.
        let err = m.sparse_push(&SparsePush {
            model: "ctr".into(),
            table: "v".into(),
            ids: vec![1],
            grads: vec![1.0],
        });
        assert!(err.is_err());
        // Unknown table.
        assert!(m
            .sparse_push(&SparsePush {
                model: "ctr".into(),
                table: "zzz".into(),
                ids: vec![1],
                grads: vec![1.0],
            })
            .is_err());
        push(&m, "v", vec![7, 7, 9], vec![0.1, 0.1, 0.2, 0.2, 0.3, 0.3]);
        let c = m.collector();
        let mut out = Vec::new();
        c.drain(&mut out);
        // 7 deduped by aggregate: two dirty ids for table v (idx 1).
        assert_eq!(out.len(), 2);
        assert!(out.iter().all(|e| e.table == 1));
    }

    #[test]
    fn dense_push_pull() {
        let (m, _) = shard(ModelKind::Fm);
        let before = m
            .dense_pull(&DensePull { model: "ctr".into(), table: "bias".into() })
            .unwrap();
        assert_eq!(before.values, vec![0.0]);
        m.dense_push(&DenseValues { model: "ctr".into(), table: "bias".into(), values: vec![1.0] })
            .unwrap();
        let after = m
            .dense_pull(&DensePull { model: "ctr".into(), table: "bias".into() })
            .unwrap();
        assert!(after.values[0] < 0.0); // moved against gradient
        assert!(m
            .dense_pull(&DensePull { model: "ctr".into(), table: "none".into() })
            .is_err());
    }

    #[test]
    fn frozen_rejects_pushes_not_pulls() {
        let (m, _) = shard(ModelKind::Lr);
        m.set_frozen(true);
        assert!(m
            .sparse_push(&SparsePush {
                model: "ctr".into(),
                table: "w".into(),
                ids: vec![1],
                grads: vec![1.0],
            })
            .is_err());
        assert!(m
            .dense_push(&DenseValues { model: "ctr".into(), table: "bias".into(), values: vec![1.0] })
            .is_err());
        let _ = pull(&m, "w", vec![1], "w"); // pulls still served
        m.set_frozen(false);
        push(&m, "w", vec![1], vec![1.0]);
    }

    #[test]
    fn snapshot_restore_round_trip() {
        let (m, _) = shard(ModelKind::Fm);
        for i in 0..50u64 {
            push(&m, "w", vec![i], vec![0.5]);
            push(&m, "v", vec![i], vec![0.1, -0.1]);
        }
        m.dense_push(&DenseValues { model: "ctr".into(), table: "bias".into(), values: vec![1.0] })
            .unwrap();
        let snap = m.snapshot();

        let (m2, _) = shard(ModelKind::Fm);
        m2.restore(&snap, None).unwrap();
        assert_eq!(m2.total_rows(), m.total_rows());
        let a = pull(&m, "v", (0..50).collect(), "*");
        let b = pull(&m2, "v", (0..50).collect(), "*");
        assert_eq!(a, b);
        let d1 = m.dense_pull(&DensePull { model: "ctr".into(), table: "bias".into() }).unwrap();
        let d2 = m2.dense_pull(&DensePull { model: "ctr".into(), table: "bias".into() }).unwrap();
        assert_eq!(d1.values, d2.values);
    }

    #[test]
    fn restore_with_router_drops_foreign_rows() {
        use crate::sync::router::Router;
        let (m, _) = shard(ModelKind::Lr);
        for i in 0..200u64 {
            push(&m, "w", vec![i], vec![1.0]);
        }
        let snap = m.snapshot();
        let (m2, _) = shard(ModelKind::Lr);
        let router = Router::new(4);
        m2.restore(&snap, Some((&router, 2))).unwrap();
        let expect = (0..200u64).filter(|id| router.shard_of(*id) == 2).count();
        assert_eq!(m2.total_rows(), expect);
    }

    #[test]
    fn absorb_merges_owned_rows_only() {
        use crate::sync::router::Router;
        let (src_a, _) = shard(ModelKind::Lr);
        let (src_b, _) = shard(ModelKind::Lr);
        for i in 0..100u64 {
            push(&src_a, "w", vec![i], vec![1.0]);
        }
        for i in 100..200u64 {
            push(&src_b, "w", vec![i], vec![1.0]);
        }
        // Migrate 2-shard content into a 3-shard cluster, shard 1.
        let router = Router::new(3);
        let (dst, _) = shard(ModelKind::Lr);
        let n1 = dst.absorb(&src_a.snapshot(), &router, 1).unwrap();
        let n2 = dst.absorb(&src_b.snapshot(), &router, 1).unwrap();
        let expect = (0..200u64).filter(|id| router.shard_of(*id) == 1).count();
        assert_eq!(n1 + n2, expect);
        assert_eq!(dst.total_rows(), expect);
    }

    #[test]
    fn expire_records_deletes() {
        let (m, clock) = shard(ModelKind::Lr);
        push(&m, "w", vec![1, 2], vec![1.0, 1.0]);
        {
            let mut scratch = Vec::new();
            m.collector().drain(&mut scratch); // clear update events
        }
        clock.advance(10_000);
        push(&m, "w", vec![2], vec![1.0]); // refresh id 2
        let evicted = m.expire_features(5_000);
        assert_eq!(evicted, 1);
        let mut events = Vec::new();
        m.collector().drain(&mut events);
        // id 2's update + id 1's delete.
        assert!(events
            .iter()
            .any(|e| e.id == 1 && e.op == crate::sync::collector::DirtyOp::Delete));
        assert_eq!(m.total_rows(), 1);
    }

    #[test]
    fn service_dispatch_round_trip() {
        let (m, _) = shard(ModelKind::Lr);
        let svc = MasterService { shard: m.clone(), store: None };
        let push_bytes = SparsePush {
            model: "ctr".into(),
            table: "w".into(),
            ids: vec![5],
            grads: vec![2.0],
        }
        .to_bytes();
        let ack = Ack::from_bytes(&svc.call(methods::SPARSE_PUSH, &push_bytes).unwrap()).unwrap();
        assert!(ack.ok);
        let pull_bytes = SparsePull {
            model: "ctr".into(),
            table: "w".into(),
            ids: vec![5],
            slot: "z".into(),
        }
        .to_bytes();
        let vals =
            SparseValues::from_bytes(&svc.call(methods::SPARSE_PULL, &pull_bytes).unwrap()).unwrap();
        assert_eq!(vals.values, vec![2.0]);
        // Checkpoint without store errors.
        let ck = CkptRequest { model: "ctr".into(), version: 1, queue_offsets: vec![] }.to_bytes();
        assert!(svc.call(methods::SAVE_CKPT, &ck).is_err());
        assert!(svc.call(99, &[]).is_err());
        // Ping.
        assert!(Ack::from_bytes(&svc.call(methods::PING, &[]).unwrap()).unwrap().ok);
    }

    #[test]
    fn delta_chunks_capture_dirty_window_and_restore_bytes() {
        let (m, _) = shard(ModelKind::Fm);
        for i in 0..40u64 {
            push(&m, "w", vec![i], vec![0.5]);
            push(&m, "v", vec![i], vec![0.1, -0.1]);
        }
        let cut = m.cut_epoch();
        // Sealed window: nothing is dirty relative to the cut.
        assert_eq!(m.dirty_counts(cut), (0, 0));
        let (m2, _) = shard(ModelKind::Fm);
        m2.restore(&m.snapshot(), None).unwrap();
        // Post-cut mutations: two sparse rows and a dense update.
        push(&m, "w", vec![3, 7], vec![1.0, 1.0]);
        m.dense_push(&DenseValues { model: "ctr".into(), table: "bias".into(), values: vec![1.0] })
            .unwrap();
        assert_eq!(m.dirty_counts(cut), (2, 0));
        let chunk = m.encode_delta(cut);
        assert_eq!((chunk.upserts, chunk.deletes), (2, 0));
        m2.apply_delta(&chunk.bytes, false).unwrap();
        assert_eq!(m.snapshot(), m2.snapshot(), "delta restore not byte-identical");
        // A truncated chunk errors cleanly, never panics.
        assert!(m2.apply_delta(&chunk.bytes[..chunk.bytes.len() / 2], false).is_err());
        // WAL-style replay marks rows dirty so the next delta reseals them.
        let (m3, _) = shard(ModelKind::Fm);
        m3.restore(&m.snapshot(), None).unwrap();
        assert_eq!(m3.dirty_counts(0), (0, 0));
        m3.apply_delta(&chunk.bytes, true).unwrap();
        assert_eq!(m3.dirty_counts(0), (2, 0));
    }

    #[test]
    fn route_guard_nacks_foreign_and_sealed_pushes() {
        use crate::reshard::SlotSet;
        use crate::sync::Router;
        let (m, _) = shard(ModelKind::Lr); // shard_id 0
        let router = Router::with_slots(2, 16);
        m.set_route_guard(router.clone());
        let map = router.snapshot();
        let mine: u64 = (0..1000).find(|&i| map.shard_of(i) == 0).unwrap();
        let foreign: u64 = (0..1000).find(|&i| map.shard_of(i) == 1).unwrap();
        push(&m, "w", vec![mine], vec![1.0]);
        let err = m
            .sparse_push(&SparsePush {
                model: "ctr".into(),
                table: "w".into(),
                ids: vec![foreign],
                grads: vec![1.0],
            })
            .unwrap_err();
        assert!(err.is_stale_route(), "{err}");
        assert_eq!(m.total_rows(), 1, "NACKed push partially applied");
        // Sealed slot: pushes NACK until unseal, nothing is dropped
        // silently.
        m.seal_slots(SlotSet::from_slots(&[map.slot_of(mine)], 16).unwrap()).unwrap();
        assert!(m
            .sparse_push(&SparsePush {
                model: "ctr".into(),
                table: "w".into(),
                ids: vec![mine],
                grads: vec![1.0],
            })
            .unwrap_err()
            .is_stale_route());
        m.unseal_slots();
        push(&m, "w", vec![mine], vec![1.0]);
        // Cutover: installing a map that moves `mine`'s slot away makes
        // the shard NACK it permanently (client re-routes).
        assert_eq!(m.route_epoch(), 0);
        let bumped = map.rebalanced(&[(map.slot_of(mine), 1)]).unwrap();
        m.install_slot_map(bumped).unwrap();
        assert_eq!(m.route_epoch(), 1);
        assert!(m
            .sparse_push(&SparsePush {
                model: "ctr".into(),
                table: "w".into(),
                ids: vec![mine],
                grads: vec![1.0],
            })
            .unwrap_err()
            .is_stale_route());
        // Pulls NACK too after the cutover — never silent zeros off a
        // (soon to be) purged donor.
        assert!(m
            .sparse_pull(&SparsePull {
                model: "ctr".into(),
                table: "w".into(),
                ids: vec![mine],
                slot: "w".into(),
            })
            .unwrap_err()
            .is_stale_route());
    }

    #[test]
    fn slot_chunks_move_rows_dirty_and_purge_is_silent() {
        use crate::reshard::{SlotMap, SlotSet};
        let (donor, _) = shard(ModelKind::Fm);
        for i in 0..80u64 {
            push(&donor, "w", vec![i], vec![0.5]);
            push(&donor, "v", vec![i], vec![0.1, -0.1]);
        }
        let universe = 16usize;
        let map = SlotMap::uniform(universe, 4);
        let set = SlotSet::from_slots(&map.slots_of(3), universe).unwrap();
        let (recip, _) = shard(ModelKind::Fm);
        let cut = recip.cut_epoch();
        let chunk = donor.encode_slot_chunk(None, &set);
        assert!(chunk.upserts > 0 && chunk.deletes == 0);
        let (up, del) = recip.apply_slot_chunk(&chunk.bytes).unwrap();
        assert_eq!((up, del), (chunk.upserts, 0));
        // Rows land dirty on the recipient: its next delta seals them.
        assert_eq!(recip.dirty_counts(cut).0, chunk.upserts);
        // Byte-identity, values *and* metadata.
        assert_eq!(
            recip.collect_slot_delta(None, &set),
            donor.collect_slot_delta(None, &set)
        );
        // Hostile input: truncation errors cleanly.
        assert!(recip.apply_slot_chunk(&chunk.bytes[..chunk.bytes.len() / 2]).is_err());
        // Retry-after-abort: a row the donor deleted between attempts
        // must not be resurrected — the base pass purges the recipient's
        // orphaned copy before re-copying.
        let dead = donor.collect_slot_delta(None, &set)[0].1[0].id;
        // Silent removal stands in for expire/delete on the donor side.
        donor.purge_slots(&SlotSet::from_slots(&[map.slot_of(dead)], universe).unwrap());
        let survivors_lost = donor.collect_slot_delta(None, &set)[0].1.len();
        let retry = donor.encode_slot_chunk(None, &set);
        recip.apply_slot_chunk(&retry.bytes).unwrap();
        let recip_rows = recip.collect_slot_delta(None, &set);
        assert!(
            recip_rows[0].1.iter().all(|r| r.id != dead),
            "deleted id {dead} resurrected by the retry base pass"
        );
        assert_eq!(recip_rows[0].1.len(), survivors_lost);
        // Purge sheds exactly the moved rows, leaving no tombstones.
        let before = donor.total_rows();
        let purged = donor.purge_slots(&set);
        assert!(purged > 0);
        assert_eq!(donor.total_rows(), before - purged);
        assert!(donor
            .collect_slot_delta(None, &set)
            .iter()
            .all(|(_, u, d)| u.is_empty() && d.is_empty()));
        // purge_foreign_rows keeps only what the map assigns here.
        let (other, _) = shard(ModelKind::Fm); // shard_id 0
        for i in 0..80u64 {
            push(&other, "w", vec![i], vec![0.5]);
        }
        let kept = (0..80u64).filter(|&i| map.shard_of(i) == 0).count();
        other.purge_foreign_rows(&map);
        assert_eq!(other.total_rows(), kept);
    }

    #[test]
    fn migrate_rpcs_dispatch() {
        let (donor, _) = shard(ModelKind::Lr);
        let (recip, _) = shard(ModelKind::Lr);
        for i in 0..50u64 {
            push(&donor, "w", vec![i], vec![2.0]);
        }
        let donor_svc = MasterService { shard: donor.clone(), store: None };
        let recip_svc = MasterService { shard: recip.clone(), store: None };
        let universe = 8u32;
        let slots: Vec<u16> = (0..8).collect();
        let pull =
            SlotPull { model: "ctr".into(), since: 0, universe, slots: slots.clone() }.to_bytes();
        let chunk = donor_svc.call(methods::MIGRATE_PULL, &pull).unwrap();
        let applied = recip_svc.call(methods::MIGRATE_APPLY, &chunk).unwrap();
        assert!(Ack::from_bytes(&applied).unwrap().ok);
        assert_eq!(recip.total_rows(), donor.total_rows());
        // Seal via RPC: the gate stands on its own, with **no route
        // guard installed** (the remote `weips master` shape) — a push
        // into the sealed slot NACKs instead of silently applying.
        let sealed_id = (0..1000u64)
            .find(|&i| crate::reshard::slot_of(i, universe as usize) == 1)
            .unwrap();
        let seal = SlotSeal { model: "ctr".into(), universe, slots: vec![1] }.to_bytes();
        donor_svc.call(methods::SEAL_SLOTS, &seal).unwrap();
        assert!(donor
            .sparse_push(&SparsePush {
                model: "ctr".into(),
                table: "w".into(),
                ids: vec![sealed_id],
                grads: vec![1.0],
            })
            .unwrap_err()
            .is_stale_route());
        let unseal = SlotSeal { model: "ctr".into(), universe, slots: vec![] }.to_bytes();
        donor_svc.call(methods::SEAL_SLOTS, &unseal).unwrap();
        push(&donor, "w", vec![sealed_id], vec![1.0]);
        let epoch = donor_svc.call(methods::ROUTE_EPOCH, &[]).unwrap();
        assert_eq!(u64::from_le_bytes(epoch.try_into().unwrap()), 0);
        // The remote release stage: purge slot 1's rows + unseal.
        let before = donor.total_rows();
        let release = SlotSeal { model: "ctr".into(), universe, slots: vec![1] }.to_bytes();
        donor_svc.call(methods::RELEASE_SLOTS, &release).unwrap();
        assert!(donor.total_rows() < before, "release purged nothing");
        // Install needs a guard; with one, the epoch advances — and a
        // mismatched universe on the migration RPCs is then rejected
        // instead of silently hashing by the wrong slot count.
        let map = crate::reshard::SlotMap::uniform(8, 2).rebalanced(&[(1, 0)]).unwrap();
        assert!(donor_svc.call(methods::INSTALL_SLOT_MAP, &map.to_bytes()).is_err());
        donor.set_route_guard(crate::sync::Router::with_slots(2, 8));
        donor_svc.call(methods::INSTALL_SLOT_MAP, &map.to_bytes()).unwrap();
        assert_eq!(donor.route_epoch(), 1);
        let wrong =
            SlotPull { model: "ctr".into(), since: 0, universe: 16, slots: vec![1] }.to_bytes();
        assert!(donor_svc.call(methods::MIGRATE_PULL, &wrong).is_err());
        // Bad slot in a pull request errors cleanly.
        let bad =
            SlotPull { model: "ctr".into(), since: 0, universe: 4, slots: vec![9] }.to_bytes();
        assert!(donor_svc.call(methods::MIGRATE_PULL, &bad).is_err());
    }

    #[test]
    fn dense_changed_since_sync_tracks_versions() {
        let (m, _) = shard(ModelKind::Fm);
        // First call: everything is "changed" (initial sync).
        let first = m.dense_changed_since_sync();
        assert_eq!(first.len(), 1);
        // No updates -> nothing to sync.
        assert!(m.dense_changed_since_sync().is_empty());
        m.dense_push(&DenseValues { model: "ctr".into(), table: "bias".into(), values: vec![1.0] })
            .unwrap();
        let after = m.dense_changed_since_sync();
        assert_eq!(after.len(), 1);
        assert_eq!(after[0].1, "bias");
    }
}
