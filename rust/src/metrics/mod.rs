//! Zero-dependency observability (ROADMAP item 4): a process-global
//! metrics registry exported in Prometheus text format by a tiny HTTP
//! endpoint on every role ([`http::MetricsServer`]).
//!
//! The build has no crates.io access, so instead of `prometheus` +
//! `hyper` this is the minimal in-tree form WeiPS needs:
//!
//! * **Declared series.** Every exported family is declared up front in
//!   [`DESCRIPTORS`] — name, type, label set, help. Registration against
//!   an undeclared family (or with the wrong label names) panics, which
//!   keeps the registry's label scheme *designed* rather than ad hoc and
//!   lets a test diff `docs/METRICS.md` against the declaration table.
//! * **Three instrument shapes.** Owned counters
//!   ([`counter`]: an `Arc<AtomicU64>` handle fetched once, recorded
//!   lock-free on the hot path), sampled values ([`register_fn`]: a
//!   closure over a `Weak` to an existing atomic/struct, read only at
//!   scrape time, silently dropped once the owner dies), and histograms
//!   ([`histogram`]: the existing log-bucketed [`crate::util::Histogram`]
//!   recording **nanoseconds**, exposed as cumulative seconds buckets).
//! * **Label scheme.** `role` (master / slave / scheduler / trainer /
//!   broker) on everything role-scoped; `shard`, `replica`, `table`,
//!   `partition`, `server` where the unit demands it; `slot_bucket` for
//!   the per-slot heat series that feed the future load-aware rebalancer
//!   (ROADMAP item 1). Aggregation adds `instance` (see [`aggregate`]).
//!
//! Re-registering the same (family, labels) replaces the previous entry,
//! so rebuilding a [`crate::coordinator::LocalCluster`] in one process
//! (tests, benches) never leaks stale sampled closures: dead `Weak`s are
//! pruned at render time, duplicates are overwritten at registration.

pub mod http;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::util::Histogram;

/// Prometheus metric type of a declared family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    /// Monotonically increasing count.
    Counter,
    /// Point-in-time value, sampled at scrape.
    Gauge,
    /// Latency distribution (recorded in ns, exported in seconds).
    Histogram,
}

impl Kind {
    fn as_str(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Histogram => "histogram",
        }
    }
}

/// Compile-time declaration of one exported series family.
#[derive(Debug)]
pub struct Desc {
    /// Family name (`weips_*`; counters end in `_total`, histograms in
    /// `_seconds`).
    pub name: &'static str,
    /// Prometheus type.
    pub kind: Kind,
    /// `# HELP` text.
    pub help: &'static str,
    /// Label names, in the order every registration must supply them.
    pub labels: &'static [&'static str],
}

/// Every series family this build can export, in exposition order.
/// `docs/METRICS.md` documents exactly this list (a test enforces it).
pub static DESCRIPTORS: &[Desc] = &[
    // -- master shard hot path ------------------------------------------
    Desc {
        name: "weips_master_pulls_total",
        kind: Kind::Counter,
        help: "Sparse pull requests handled by a master shard.",
        labels: &["role", "shard"],
    },
    Desc {
        name: "weips_master_pushes_total",
        kind: Kind::Counter,
        help: "Sparse push (gradient) requests handled by a master shard.",
        labels: &["role", "shard"],
    },
    Desc {
        name: "weips_master_push_rows_total",
        kind: Kind::Counter,
        help: "Parameter rows updated by sparse pushes on a master shard.",
        labels: &["role", "shard"],
    },
    Desc {
        name: "weips_master_rows",
        kind: Kind::Gauge,
        help: "Live sparse parameter rows resident in a master shard.",
        labels: &["role", "shard"],
    },
    Desc {
        name: "weips_master_table_rows",
        kind: Kind::Gauge,
        help: "Live sparse parameter rows per table in a master shard.",
        labels: &["role", "shard", "table"],
    },
    Desc {
        name: "weips_table_row_store_info",
        kind: Kind::Gauge,
        help: "Info gauge (constant 1): the row-value backing actually engaged by a \
               master shard's tables (store = arena | boxed).",
        labels: &["role", "shard", "store"],
    },
    // -- slave serving path ---------------------------------------------
    Desc {
        name: "weips_slave_pulls_total",
        kind: Kind::Counter,
        help: "Serving pull requests handled by a slave replica.",
        labels: &["role", "shard", "replica"],
    },
    Desc {
        name: "weips_slave_applied_entries_total",
        kind: Kind::Counter,
        help: "Sync entries applied to a slave replica's serving tables.",
        labels: &["role", "shard", "replica"],
    },
    Desc {
        name: "weips_slave_filtered_entries_total",
        kind: Kind::Counter,
        help: "Sync entries skipped because their id routes to another slave shard.",
        labels: &["role", "shard", "replica"],
    },
    Desc {
        name: "weips_slave_rows",
        kind: Kind::Gauge,
        help: "Live serving rows resident in a slave replica.",
        labels: &["role", "shard", "replica"],
    },
    Desc {
        name: "weips_stripe_lock_acquisitions_total",
        kind: Kind::Counter,
        help: "Serving-table stripe write-locks taken by streaming applies \
               (coalescing makes this grow sub-linearly in batch count).",
        labels: &["role", "shard", "replica"],
    },
    // -- sync pipeline stages (gather -> queue -> scatter) ---------------
    Desc {
        name: "weips_gather_raw_events_total",
        kind: Kind::Counter,
        help: "Raw dirty events drained from the update collector by the gather stage.",
        labels: &["role", "shard"],
    },
    Desc {
        name: "weips_gather_emitted_entries_total",
        kind: Kind::Counter,
        help: "Entries emitted into sync batches after windowed dedup.",
        labels: &["role", "shard"],
    },
    Desc {
        name: "weips_gather_batches_total",
        kind: Kind::Counter,
        help: "Sync batches emitted by the gather stage.",
        labels: &["role", "shard"],
    },
    Desc {
        name: "weips_gather_empty_polls_total",
        kind: Kind::Counter,
        help: "Gather flush polls that found no dirty updates.",
        labels: &["role", "shard"],
    },
    Desc {
        name: "weips_queue_depth_records",
        kind: Kind::Gauge,
        help: "Records currently retained in one sync-queue partition.",
        labels: &["role", "partition"],
    },
    Desc {
        name: "weips_scatter_batches_applied_total",
        kind: Kind::Counter,
        help: "Sync batches consumed from the queue and applied by a scatter worker.",
        labels: &["role", "shard", "replica"],
    },
    Desc {
        name: "weips_scatter_decode_errors_total",
        kind: Kind::Counter,
        help: "Queue records a scatter worker failed to decompress or decode.",
        labels: &["role", "shard", "replica"],
    },
    Desc {
        name: "weips_scatter_lag_records",
        kind: Kind::Gauge,
        help: "Records between a scatter worker's cursors and the queue log end \
               (sampled after each poll).",
        labels: &["role", "shard", "replica"],
    },
    Desc {
        name: "weips_push_visible_latency_seconds",
        kind: Kind::Histogram,
        help: "Latency from a sync batch's creation on the master to its rows \
               becoming visible in a slave replica's serving tables.",
        labels: &["role", "shard", "replica"],
    },
    Desc {
        name: "weips_trace_stage_duration_seconds",
        kind: Kind::Histogram,
        help: "Per-stage duration of sampled update-journey traces (stage names are \
               declared in trace::STAGES; populated only when trace_sample_every > 0).",
        labels: &["role", "stage"],
    },
    // -- durability (WAL + checkpoints) ----------------------------------
    Desc {
        name: "weips_wal_appends_total",
        kind: Kind::Counter,
        help: "Records appended to the write-ahead log.",
        labels: &["role"],
    },
    Desc {
        name: "weips_wal_fsyncs_total",
        kind: Kind::Counter,
        help: "fsync(2) calls issued by the WAL (cadence = wal_sync_every).",
        labels: &["role"],
    },
    Desc {
        name: "weips_wal_unsynced_appends",
        kind: Kind::Gauge,
        help: "WAL appends since the last fsync — the fsync lag a power loss could lose \
               (flush-only mode grows without bound by design).",
        labels: &["role"],
    },
    Desc {
        name: "weips_wal_fsync_duration_seconds",
        kind: Kind::Histogram,
        help: "Wall time of WAL fsync(2) calls.",
        labels: &["role"],
    },
    Desc {
        name: "weips_checkpoints_total",
        kind: Kind::Counter,
        help: "Checkpoints sealed by the scheduler (base + incremental).",
        labels: &["role"],
    },
    Desc {
        name: "weips_ckpt_mmap_engaged",
        kind: Kind::Gauge,
        help: "Whether checkpoint/delta chunk loads actually use the mmap fast path \
               (1) or the streamed read fallback (0).",
        labels: &["role"],
    },
    // -- RPC substrate ---------------------------------------------------
    Desc {
        name: "weips_rpc_dispatches_total",
        kind: Kind::Counter,
        help: "Worker dispatches submitted by an RPC server's poll thread \
               (ready-set batching makes this grow slower than connections).",
        labels: &["server"],
    },
    Desc {
        name: "weips_rpc_dispatched_connections_total",
        kind: Kind::Counter,
        help: "Ready connections handed to RPC worker threads.",
        labels: &["server"],
    },
    Desc {
        name: "weips_rpc_parked_connections",
        kind: Kind::Gauge,
        help: "Idle connections currently parked in an RPC server's event loop.",
        labels: &["server"],
    },
    Desc {
        name: "weips_rpc_engaged_poll_mode",
        kind: Kind::Gauge,
        help: "Info gauge (constant 1): the readiness backend an RPC server actually \
               engaged after degradation (mode = uring | event | peek) — may differ \
               from the configured rpc_poll_mode.",
        labels: &["server", "mode"],
    },
    Desc {
        name: "weips_rpc_class_dispatches_total",
        kind: Kind::Counter,
        help: "Requests admitted per QoS class (predict/bulk/control) by an RPC \
               server's admission gate.",
        labels: &["server", "class"],
    },
    Desc {
        name: "weips_rpc_class_shed_total",
        kind: Kind::Counter,
        help: "Requests shed with the typed overload NACK because their QoS class \
               was at its in-flight cap.",
        labels: &["server", "class"],
    },
    // -- serving read path (hot-id cache + replica fan-out) ---------------
    Desc {
        name: "weips_cache_hits_total",
        kind: Kind::Counter,
        help: "Pulled ids served from the predictor's hot-id cache.",
        labels: &["role"],
    },
    Desc {
        name: "weips_cache_misses_total",
        kind: Kind::Counter,
        help: "Pulled ids that missed the hot-id cache and were fetched remotely.",
        labels: &["role"],
    },
    Desc {
        name: "weips_cache_invalidations_total",
        kind: Kind::Counter,
        help: "Cache rows invalidated by the streaming scatter tap (the epoch-based \
               coherence channel — no TTL).",
        labels: &["role"],
    },
    Desc {
        name: "weips_pull_fanout_latency_seconds",
        kind: Kind::Histogram,
        help: "Per-shard remote pull latency observed by the replica-aware fan-out \
               (cache misses only; hits never leave the process).",
        labels: &["role"],
    },
    // -- routing / elastic resharding ------------------------------------
    Desc {
        name: "weips_routing_epoch",
        kind: Kind::Gauge,
        help: "Current slot-map epoch observed by this role's router (0 = canonical \
               uniform map).",
        labels: &["role"],
    },
    Desc {
        name: "weips_slot_pushes_total",
        kind: Kind::Counter,
        help: "Push rows per virtual-slot bucket — the write-heat input for the \
               load-aware rebalancer.",
        labels: &["role", "slot_bucket"],
    },
    Desc {
        name: "weips_slot_pulls_total",
        kind: Kind::Counter,
        help: "Pulled ids per virtual-slot bucket — the read-heat input for the \
               load-aware rebalancer.",
        labels: &["role", "slot_bucket"],
    },
    Desc {
        name: "weips_migrations_total",
        kind: Kind::Counter,
        help: "Completed live slot migrations.",
        labels: &["role"],
    },
    Desc {
        name: "weips_migration_slots_moved_total",
        kind: Kind::Counter,
        help: "Virtual slots re-assigned by completed migrations.",
        labels: &["role"],
    },
    Desc {
        name: "weips_migration_rows_moved_total",
        kind: Kind::Counter,
        help: "Parameter rows copied by completed migrations (base + catch-up + final).",
        labels: &["role"],
    },
    // -- model quality (progressive validation) --------------------------
    Desc {
        name: "weips_model_auc",
        kind: Kind::Gauge,
        help: "Cumulative progressive-validation AUC.",
        labels: &["role"],
    },
    Desc {
        name: "weips_model_window_auc",
        kind: Kind::Gauge,
        help: "Sliding-window progressive-validation AUC (the downgrade-trigger input).",
        labels: &["role"],
    },
    Desc {
        name: "weips_model_logloss",
        kind: Kind::Gauge,
        help: "Cumulative mean logloss of pre-update predictions.",
        labels: &["role"],
    },
    Desc {
        name: "weips_model_calibration",
        kind: Kind::Gauge,
        help: "Mean prediction / mean label (1.0 = perfectly calibrated).",
        labels: &["role"],
    },
    Desc {
        name: "weips_model_samples",
        kind: Kind::Gauge,
        help: "Training samples observed by the progressive-validation monitor.",
        labels: &["role"],
    },
    // -- alerting / event journal -----------------------------------------
    Desc {
        name: "weips_alert_state",
        kind: Kind::Gauge,
        help: "Lifecycle state of a declared alert rule (0 = ok, 1 = pending, \
               2 = firing); rules are declared in alerts::RULES.",
        labels: &["rule", "severity"],
    },
    Desc {
        name: "weips_alert_eval_duration_seconds",
        kind: Kind::Histogram,
        help: "Wall time of one alert-evaluator tick over every declared rule.",
        labels: &["role"],
    },
];

/// Histogram bucket bounds: exposition label (seconds) paired with the
/// recorded-nanosecond bound. Chosen to straddle both fsync (µs..ms) and
/// push→visible (ms..s) latencies.
pub const LATENCY_LE_NS: &[(&str, u64)] = &[
    ("0.000001", 1_000),
    ("0.00001", 10_000),
    ("0.0001", 100_000),
    ("0.001", 1_000_000),
    ("0.01", 10_000_000),
    ("0.05", 50_000_000),
    ("0.1", 100_000_000),
    ("0.5", 500_000_000),
    ("1", 1_000_000_000),
    ("5", 5_000_000_000),
    ("10", 10_000_000_000),
];

/// A scrape-time sampler: returns the current value, or `None` once the
/// owning component is gone (the entry is then pruned).
pub type SampleFn = Box<dyn Fn() -> Option<f64> + Send + Sync>;

enum Instrument {
    Counter(Arc<AtomicU64>),
    Sampled(SampleFn),
    Histogram(Arc<Histogram>),
}

/// The metrics registry: family name → label-set → instrument.
pub struct Registry {
    families: Mutex<BTreeMap<&'static str, BTreeMap<String, Instrument>>>,
}

impl Registry {
    fn new() -> Registry {
        Registry { families: Mutex::new(BTreeMap::new()) }
    }

    fn desc(name: &str) -> &'static Desc {
        DESCRIPTORS
            .iter()
            .find(|d| d.name == name)
            .unwrap_or_else(|| panic!("metrics: series {name} is not declared in DESCRIPTORS"))
    }

    /// Validate the label names against the declaration and render the
    /// stable `k="v",...` key.
    fn label_key(desc: &Desc, labels: &[(&'static str, String)]) -> String {
        assert_eq!(
            labels.iter().map(|(k, _)| *k).collect::<Vec<_>>(),
            desc.labels,
            "metrics: {} registered with wrong label names",
            desc.name
        );
        labels
            .iter()
            .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Get-or-create an owned counter handle. The returned `Arc` is the
    /// live instrument: record with `fetch_add` on the hot path.
    pub fn counter(&self, name: &'static str, labels: &[(&'static str, String)]) -> Arc<AtomicU64> {
        let desc = Self::desc(name);
        debug_assert_eq!(desc.kind, Kind::Counter, "{name} is not a counter");
        let key = Self::label_key(desc, labels);
        let mut fams = self.families.lock().unwrap();
        let fam = fams.entry(desc.name).or_default();
        if let Some(Instrument::Counter(c)) = fam.get(&key) {
            return c.clone();
        }
        let c = Arc::new(AtomicU64::new(0));
        fam.insert(key, Instrument::Counter(c.clone()));
        c
    }

    /// Get-or-create a histogram handle. Record **nanoseconds**; the
    /// exposition converts to seconds.
    pub fn histogram(
        &self,
        name: &'static str,
        labels: &[(&'static str, String)],
    ) -> Arc<Histogram> {
        let desc = Self::desc(name);
        debug_assert_eq!(desc.kind, Kind::Histogram, "{name} is not a histogram");
        let key = Self::label_key(desc, labels);
        let mut fams = self.families.lock().unwrap();
        let fam = fams.entry(desc.name).or_default();
        if let Some(Instrument::Histogram(h)) = fam.get(&key) {
            return h.clone();
        }
        let h = Arc::new(Histogram::new());
        fam.insert(key, Instrument::Histogram(h.clone()));
        h
    }

    /// Register (or replace) a scrape-time sampler for a counter or gauge
    /// family. The closure should capture a `Weak` to its owner and
    /// return `None` once the owner is dropped.
    pub fn register_fn(
        &self,
        name: &'static str,
        labels: &[(&'static str, String)],
        f: SampleFn,
    ) {
        let desc = Self::desc(name);
        debug_assert_ne!(desc.kind, Kind::Histogram, "{name}: use histogram() instead");
        let key = Self::label_key(desc, labels);
        let mut fams = self.families.lock().unwrap();
        fams.entry(desc.name).or_default().insert(key, Instrument::Sampled(f));
    }

    /// Render the full Prometheus text exposition. Every declared family
    /// gets its `# HELP`/`# TYPE` header even when it has no samples yet,
    /// so the series reference stays diffable against any scrape. Dead
    /// samplers (owner dropped) are pruned here.
    pub fn render(&self) -> String {
        let mut out = String::with_capacity(16 * 1024);
        let mut fams = self.families.lock().unwrap();
        for desc in DESCRIPTORS {
            out.push_str("# HELP ");
            out.push_str(desc.name);
            out.push(' ');
            out.push_str(&desc.help.split_whitespace().collect::<Vec<_>>().join(" "));
            out.push('\n');
            out.push_str("# TYPE ");
            out.push_str(desc.name);
            out.push(' ');
            out.push_str(desc.kind.as_str());
            out.push('\n');
            let Some(fam) = fams.get_mut(desc.name) else { continue };
            let mut dead = Vec::new();
            for (key, inst) in fam.iter() {
                match inst {
                    Instrument::Counter(c) => {
                        sample_line(&mut out, desc.name, key, c.load(Ordering::Relaxed) as f64);
                    }
                    Instrument::Sampled(f) => match f() {
                        Some(v) => sample_line(&mut out, desc.name, key, v),
                        None => dead.push(key.clone()),
                    },
                    Instrument::Histogram(h) => render_histogram(&mut out, desc.name, key, h),
                }
            }
            for key in dead {
                fam.remove(&key);
            }
        }
        out
    }

    /// Sum a family's current value across every live series (counter
    /// loads, sampled reads, histogram counts). Dead samplers are pruned;
    /// `None` when the family has no live series yet — the alert
    /// evaluator's rate queries use this as their input.
    pub fn family_total(&self, name: &'static str) -> Option<f64> {
        let desc = Self::desc(name);
        let mut fams = self.families.lock().unwrap();
        let fam = fams.get_mut(desc.name)?;
        let mut sum = 0.0;
        let mut live = 0usize;
        let mut dead = Vec::new();
        for (key, inst) in fam.iter() {
            match inst {
                Instrument::Counter(c) => {
                    sum += c.load(Ordering::Relaxed) as f64;
                    live += 1;
                }
                Instrument::Sampled(f) => match f() {
                    Some(v) => {
                        sum += v;
                        live += 1;
                    }
                    None => dead.push(key.clone()),
                },
                Instrument::Histogram(h) => {
                    sum += h.count() as f64;
                    live += 1;
                }
            }
        }
        for key in dead {
            fam.remove(&key);
        }
        (live > 0).then_some(sum)
    }

    /// Approximate quantile (in seconds) of a histogram family, merging
    /// the cumulative buckets of every series. Returns the upper bound of
    /// the bucket holding the rank — the same resolution the exposition
    /// offers a dashboard — or `f64::INFINITY` past the largest bound;
    /// `None` while the family has no observations.
    pub fn family_quantile(&self, name: &'static str, q: f64) -> Option<f64> {
        let desc = Self::desc(name);
        debug_assert_eq!(desc.kind, Kind::Histogram, "{name} is not a histogram");
        let fams = self.families.lock().unwrap();
        let fam = fams.get(desc.name)?;
        let bounds: Vec<u64> = LATENCY_LE_NS.iter().map(|(_, b)| *b).collect();
        let mut cum = vec![0u64; bounds.len()];
        let mut total = 0u64;
        for inst in fam.values() {
            if let Instrument::Histogram(h) = inst {
                for (i, c) in h.cumulative(&bounds).iter().enumerate() {
                    cum[i] += c;
                }
                total += h.count();
            }
        }
        if total == 0 {
            return None;
        }
        let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        for (i, c) in cum.iter().enumerate() {
            if *c >= rank {
                return Some(bounds[i] as f64 / 1e9);
            }
        }
        Some(f64::INFINITY)
    }
}

/// Append `name{key} value\n` (omitting the braces for an empty key).
fn sample_line(out: &mut String, name: &str, key: &str, value: f64) {
    out.push_str(name);
    if !key.is_empty() {
        out.push('{');
        out.push_str(key);
        out.push('}');
    }
    out.push(' ');
    out.push_str(&fmt_value(value));
    out.push('\n');
}

fn render_histogram(out: &mut String, name: &str, key: &str, h: &Histogram) {
    let bounds: Vec<u64> = LATENCY_LE_NS.iter().map(|(_, b)| *b).collect();
    let cum = h.cumulative(&bounds);
    let total = h.count();
    // A linked trace exemplar attaches to the first bucket that holds its
    // observation (LATENCY_LE_NS.len() = the +Inf bucket).
    let exemplar = exemplar_for(name, key);
    let exemplar_bucket = exemplar.map(|(_, v)| {
        bounds.iter().position(|b| v * 1e9 <= *b as f64).unwrap_or(LATENCY_LE_NS.len())
    });
    let push_exemplar = |out: &mut String| {
        if let Some((id, v)) = exemplar {
            out.push_str(" # {trace_id=\"");
            out.push_str(&format!("{id:016x}"));
            out.push_str("\"} ");
            out.push_str(&fmt_value(v));
        }
    };
    for (i, ((le, _), c)) in LATENCY_LE_NS.iter().zip(&cum).enumerate() {
        out.push_str(name);
        out.push_str("_bucket{");
        if !key.is_empty() {
            out.push_str(key);
            out.push(',');
        }
        out.push_str("le=\"");
        out.push_str(le);
        out.push_str("\"} ");
        // A record between the bucket sweep and the count read can make a
        // bucket momentarily exceed the total; clamp for monotonicity.
        out.push_str(&(*c).min(total).to_string());
        if exemplar_bucket == Some(i) {
            push_exemplar(out);
        }
        out.push('\n');
    }
    out.push_str(name);
    out.push_str("_bucket{");
    if !key.is_empty() {
        out.push_str(key);
        out.push(',');
    }
    out.push_str("le=\"+Inf\"} ");
    out.push_str(&total.to_string());
    if exemplar_bucket == Some(LATENCY_LE_NS.len()) {
        push_exemplar(out);
    }
    out.push('\n');
    sample_line(out, &format!("{name}_sum"), key, h.sum() as f64 / 1e9);
    sample_line(out, &format!("{name}_count"), key, total as f64);
}

/// Prometheus-friendly float formatting: integral values print without a
/// fractional part.
fn fmt_value(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// The process-global registry all convenience functions below use.
pub fn default() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(Registry::new)
}

/// [`Registry::counter`] on the global registry.
pub fn counter(name: &'static str, labels: &[(&'static str, String)]) -> Arc<AtomicU64> {
    default().counter(name, labels)
}

/// [`Registry::histogram`] on the global registry.
pub fn histogram(name: &'static str, labels: &[(&'static str, String)]) -> Arc<Histogram> {
    default().histogram(name, labels)
}

/// [`Registry::register_fn`] on the global registry.
pub fn register_fn(name: &'static str, labels: &[(&'static str, String)], f: SampleFn) {
    default().register_fn(name, labels, f)
}

/// [`Registry::render`] on the global registry.
pub fn render() -> String {
    default().render()
}

/// [`Registry::family_total`] on the global registry.
pub fn family_total(name: &'static str) -> Option<f64> {
    default().family_total(name)
}

/// [`Registry::family_quantile`] on the global registry.
pub fn family_quantile(name: &'static str, q: f64) -> Option<f64> {
    default().family_quantile(name, q)
}

// ---------------------------------------------------------------------------
// OpenMetrics exemplars (trace linkage)
// ---------------------------------------------------------------------------

/// Last sampled exemplar per histogram series: (family, label key) →
/// (trace id, observed value in seconds).
fn exemplars() -> &'static Mutex<BTreeMap<(String, String), (u64, f64)>> {
    static EX: OnceLock<Mutex<BTreeMap<(String, String), (u64, f64)>>> = OnceLock::new();
    EX.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Link a sampled trace to a histogram series as an OpenMetrics exemplar:
/// the exposition appends ``# {trace_id="<hex>"} <value>`` to the bucket
/// the observation falls in, so a dashboard can jump from a latency
/// bucket straight to `/trace/<hex>`. The newest exemplar per series
/// wins. Panics if `name` is not a declared histogram family.
pub fn set_exemplar(
    name: &'static str,
    labels: &[(&'static str, String)],
    trace_id: u64,
    value_seconds: f64,
) {
    let desc = Registry::desc(name);
    debug_assert_eq!(desc.kind, Kind::Histogram, "{name}: exemplars attach to histograms");
    let key = Registry::label_key(desc, labels);
    exemplars().lock().unwrap().insert((name.to_string(), key), (trace_id, value_seconds));
}

fn exemplar_for(name: &str, key: &str) -> Option<(u64, f64)> {
    exemplars().lock().unwrap().get(&(name.to_string(), key.to_string())).copied()
}

/// Most recent exemplar trace id attached to any series of one histogram
/// family — the alert evaluator cites it when a latency rule transitions,
/// correlating the journal entry with a sampled batch.
pub fn exemplar_trace_id(name: &str) -> Option<u64> {
    exemplars()
        .lock()
        .unwrap()
        .iter()
        .filter(|((n, _), _)| n.as_str() == name)
        .map(|(_, (id, _))| *id)
        .next_back()
}

/// Drop the ``# {...}`` exemplar suffix from one exposition line (the
/// parser and the `/cluster` aggregator both work on plain samples).
fn strip_exemplar(line: &str) -> &str {
    match line.find(" # ") {
        Some(p) => line[..p].trim_end(),
        None => line,
    }
}

// ---------------------------------------------------------------------------
// Readiness probes (/healthz degraded levels)
// ---------------------------------------------------------------------------

/// Every readiness probe `/healthz` evaluates: (name, display text).
/// Like [`DESCRIPTORS`], registering an undeclared probe panics. Since
/// PR 10 the probe values and bounds live in the alert engine's source
/// registry ([`crate::alerts::SOURCES`]) — readiness and the declared
/// alert rules share one registration and one bound store, so the two
/// can never drift (an `alerts` test pins every probe to a rule).
pub static HEALTH_PROBES: &[(&str, &str)] = &[
    ("scatter_lag_records", "scatter lag"),
    ("wal_unsynced_appends", "WAL unsynced appends"),
];

fn health_what(name: &str) -> &'static str {
    HEALTH_PROBES
        .iter()
        .find(|(n, _)| *n == name)
        .map(|(_, what)| *what)
        .unwrap_or_else(|| panic!("metrics: health probe {name} is not declared in HEALTH_PROBES"))
}

/// Register (or replace) a readiness probe. `detail` locates the owner
/// (e.g. `shard=0 replica=1`); the closure follows the [`SampleFn`]
/// contract — `None` once the owner is dropped prunes the entry.
/// Delegates to [`crate::alerts::register_source`]: the same sample
/// feeds `/healthz` and the declared alert rules.
pub fn register_health(name: &'static str, detail: String, f: SampleFn) {
    health_what(name);
    crate::alerts::register_source(name, detail, f);
}

/// Set (or clear) the degradation bound for a declared probe. `None` or
/// a non-positive bound disables the readiness check; the probe keeps
/// sampling. Delegates to [`crate::alerts::set_source_bound`], the one
/// bound store readiness and alerting share.
pub fn set_health_bound(name: &'static str, bound: Option<f64>) {
    health_what(name);
    crate::alerts::set_source_bound(name, bound);
}

/// `/healthz` body: `ok` while every bounded probe is under its bound,
/// else `degraded: <reasons>`. Always served with HTTP 200 — fleet
/// probes that only check the status code keep treating a degraded
/// (alive-but-stale) role as alive; readiness checks match on the body.
pub fn health_body() -> String {
    let mut reasons = Vec::new();
    for (name, what) in HEALTH_PROBES {
        let Some(bound) = crate::alerts::source_bound(name) else {
            // Unbounded probes still sample (pruning dead owners).
            crate::alerts::sample_source(name);
            continue;
        };
        for (detail, v) in crate::alerts::sample_source(name) {
            if v > bound {
                reasons.push(format!(
                    "{what} {} > {} ({detail})",
                    fmt_value(v),
                    fmt_value(bound)
                ));
            }
        }
    }
    if reasons.is_empty() {
        "ok\n".to_string()
    } else {
        format!("degraded: {}\n", reasons.join("; "))
    }
}

// ---------------------------------------------------------------------------
// Exposition parsing + cluster aggregation
// ---------------------------------------------------------------------------

/// One parsed sample line.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

impl Sample {
    /// Value of one label (None when absent).
    pub fn label(&self, key: &str) -> Option<&str> {
        self.labels.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }
}

/// Parse a Prometheus text exposition into samples. Comment (`#`) and
/// blank lines are skipped; any other malformed line is an error — the
/// integration tests use this to assert every scrape parses.
pub fn parse_exposition(text: &str) -> std::result::Result<Vec<Sample>, String> {
    let mut out = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        // Exemplar suffixes carry braces of their own; strip before the
        // brace-matching sample parse.
        let line = strip_exemplar(line);
        out.push(parse_sample(line).map_err(|e| format!("line {}: {e}: {line}", ln + 1))?);
    }
    Ok(out)
}

fn parse_sample(line: &str) -> std::result::Result<Sample, String> {
    let (name_part, rest) = match line.find('{') {
        Some(brace) => {
            let close = line.rfind('}').ok_or("unterminated label set")?;
            if close < brace {
                return Err("mismatched braces".into());
            }
            (&line[..brace], &line[close + 1..])
        }
        None => {
            let sp = line.find(char::is_whitespace).ok_or("no value")?;
            (&line[..sp], &line[sp..])
        }
    };
    let name = name_part.trim().to_string();
    if name.is_empty() || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
    {
        return Err(format!("bad metric name {name:?}"));
    }
    let labels = match line.find('{') {
        Some(brace) => parse_labels(&line[brace + 1..brace + (line.rfind('}').unwrap() - brace)])?,
        None => Vec::new(),
    };
    let vs = rest.trim();
    let value = match vs {
        "+Inf" => f64::INFINITY,
        "-Inf" => f64::NEG_INFINITY,
        _ => vs.parse::<f64>().map_err(|_| format!("bad value {vs:?}"))?,
    };
    Ok(Sample { name, labels, value })
}

fn parse_labels(body: &str) -> std::result::Result<Vec<(String, String)>, String> {
    let mut labels = Vec::new();
    let mut chars = body.chars().peekable();
    loop {
        while matches!(chars.peek(), Some(c) if c.is_whitespace() || *c == ',') {
            chars.next();
        }
        if chars.peek().is_none() {
            return Ok(labels);
        }
        let mut key = String::new();
        for c in chars.by_ref() {
            if c == '=' {
                break;
            }
            key.push(c);
        }
        if chars.next() != Some('"') {
            return Err(format!("label {key}: expected opening quote"));
        }
        let mut value = String::new();
        let mut closed = false;
        while let Some(c) = chars.next() {
            match c {
                '\\' => match chars.next() {
                    Some('n') => value.push('\n'),
                    Some(other) => value.push(other),
                    None => return Err("dangling escape".into()),
                },
                '"' => {
                    closed = true;
                    break;
                }
                _ => value.push(c),
            }
        }
        if !closed {
            return Err(format!("label {key}: unterminated value"));
        }
        labels.push((key.trim().to_string(), value));
    }
}

/// Merge per-role scrapes into one cluster-wide exposition: each sample
/// line gains an `instance="<addr>"` label; `# HELP`/`# TYPE` headers are
/// emitted once per family from [`DESCRIPTORS`]. Sample names that don't
/// belong to any declared family are dropped (a scrape from a newer build
/// degrades gracefully instead of corrupting the merged view).
pub fn aggregate(scrapes: &[(String, String)]) -> String {
    // sample name -> descriptor index (histograms expose three suffixes).
    let mut index: BTreeMap<String, usize> = BTreeMap::new();
    for (i, d) in DESCRIPTORS.iter().enumerate() {
        index.insert(d.name.to_string(), i);
        if d.kind == Kind::Histogram {
            index.insert(format!("{}_bucket", d.name), i);
            index.insert(format!("{}_sum", d.name), i);
            index.insert(format!("{}_count", d.name), i);
        }
    }
    let mut per_family: Vec<Vec<String>> = vec![Vec::new(); DESCRIPTORS.len()];
    for (instance, body) in scrapes {
        for line in body.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            // Exemplars are per-process detail; the merged view carries
            // plain samples only (and stays parseable).
            let line = strip_exemplar(line);
            let name_end = line.find(|c: char| c == '{' || c.is_whitespace()).unwrap_or(0);
            let Some(&fam) = index.get(&line[..name_end]) else { continue };
            let tagged = match line.find('{') {
                Some(brace) => {
                    let empty = line[brace + 1..].trim_start().starts_with('}');
                    format!(
                        "{}{{instance=\"{}\"{}{}",
                        &line[..brace],
                        escape_label(instance),
                        if empty { "" } else { "," },
                        &line[brace + 1..]
                    )
                }
                None => format!(
                    "{}{{instance=\"{}\"}}{}",
                    &line[..name_end],
                    escape_label(instance),
                    &line[name_end..]
                ),
            };
            per_family[fam].push(tagged);
        }
    }
    let mut out = String::with_capacity(32 * 1024);
    for (desc, lines) in DESCRIPTORS.iter().zip(&per_family) {
        out.push_str("# HELP ");
        out.push_str(desc.name);
        out.push(' ');
        out.push_str(&desc.help.split_whitespace().collect::<Vec<_>>().join(" "));
        out.push('\n');
        out.push_str("# TYPE ");
        out.push_str(desc.name);
        out.push(' ');
        out.push_str(desc.kind.as_str());
        out.push('\n');
        for l in lines {
            out.push_str(l);
            out.push('\n');
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn descriptor_names_unique_and_well_formed() {
        let mut seen = std::collections::BTreeSet::new();
        for d in DESCRIPTORS {
            assert!(seen.insert(d.name), "duplicate family {}", d.name);
            assert!(d.name.starts_with("weips_"), "{} must be weips_-prefixed", d.name);
            match d.kind {
                Kind::Counter => assert!(d.name.ends_with("_total"), "{}", d.name),
                Kind::Histogram => assert!(d.name.ends_with("_seconds"), "{}", d.name),
                Kind::Gauge => {
                    assert!(!d.name.ends_with("_total"), "{} gauge ends in _total", d.name)
                }
            }
            assert!(!d.labels.contains(&"instance"), "{}: instance is reserved", d.name);
        }
    }

    #[test]
    fn counter_roundtrip_and_render() {
        let c = counter(
            "weips_master_pulls_total",
            &[("role", "unit-test".into()), ("shard", "77".into())],
        );
        c.fetch_add(41, Ordering::Relaxed);
        // Get-or-create returns the same instrument.
        counter(
            "weips_master_pulls_total",
            &[("role", "unit-test".into()), ("shard", "77".into())],
        )
        .fetch_add(1, Ordering::Relaxed);
        let text = render();
        assert!(text.contains("# TYPE weips_master_pulls_total counter"));
        assert!(
            text.contains("weips_master_pulls_total{role=\"unit-test\",shard=\"77\"} 42"),
            "{text}"
        );
    }

    #[test]
    fn sampler_prunes_after_owner_drops() {
        let owner = Arc::new(AtomicU64::new(7));
        let weak = Arc::downgrade(&owner);
        register_fn(
            "weips_routing_epoch",
            &[("role", "unit-test-prune".into())],
            Box::new(move || weak.upgrade().map(|a| a.load(Ordering::Relaxed) as f64)),
        );
        assert!(render().contains("weips_routing_epoch{role=\"unit-test-prune\"} 7"));
        drop(owner);
        assert!(!render().contains("role=\"unit-test-prune\""));
    }

    #[test]
    fn histogram_renders_cumulative_seconds_buckets() {
        let h = histogram(
            "weips_wal_fsync_duration_seconds",
            &[("role", "unit-test-hist".into())],
        );
        h.record(500);            // 0.5µs
        h.record(2_000_000);      // 2ms
        h.record(2_000_000_000);  // 2s
        let text = render();
        let line = |le: &str| {
            format!("weips_wal_fsync_duration_seconds_bucket{{role=\"unit-test-hist\",le=\"{le}\"}}")
        };
        let bucket = |le: &str| -> u64 {
            text.lines()
                .find(|l| l.starts_with(&line(le)))
                .unwrap_or_else(|| panic!("missing bucket {le}"))
                .rsplit(' ')
                .next()
                .unwrap()
                .parse()
                .unwrap()
        };
        assert_eq!(bucket("0.000001"), 1);
        assert_eq!(bucket("0.01"), 2); // log-bucket midpoint keeps 2ms under 10ms
        assert_eq!(bucket("+Inf"), 3);
        assert!(bucket("0.001") <= bucket("0.01"), "cumulative monotone");
        assert!(text
            .contains("weips_wal_fsync_duration_seconds_count{role=\"unit-test-hist\"} 3"));
    }

    #[test]
    fn render_emits_every_declared_family_header() {
        let text = render();
        for d in DESCRIPTORS {
            assert!(
                text.contains(&format!("# TYPE {} {}", d.name, d.kind.as_str())),
                "family {} missing from render",
                d.name
            );
        }
    }

    #[test]
    fn parse_roundtrips_own_render() {
        counter(
            "weips_master_pushes_total",
            &[("role", "unit-test-parse".into()), ("shard", "3".into())],
        )
        .fetch_add(5, Ordering::Relaxed);
        let samples = parse_exposition(&render()).expect("own exposition must parse");
        let s = samples
            .iter()
            .find(|s| {
                s.name == "weips_master_pushes_total" && s.label("role") == Some("unit-test-parse")
            })
            .expect("sample present");
        assert_eq!(s.label("shard"), Some("3"));
        assert_eq!(s.value, 5.0);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_exposition("weips_x{role=\"a\" 1").is_err());
        assert!(parse_exposition("no value here").is_err());
        assert!(parse_exposition("m 1.5\n# comment\n\nm2{a=\"b\"} 2").is_ok());
    }

    #[test]
    fn parse_handles_escapes() {
        let s = parse_sample(r#"m{a="x\"y\\z"} 1"#).unwrap();
        assert_eq!(s.label("a"), Some("x\"y\\z"));
    }

    #[test]
    fn exemplar_attaches_to_bucket_and_stays_parseable() {
        let h = histogram(
            "weips_push_visible_latency_seconds",
            &[
                ("role", "unit-test-ex".into()),
                ("shard", "0".into()),
                ("replica", "0".into()),
            ],
        );
        h.record(2_000_000); // 2ms
        set_exemplar(
            "weips_push_visible_latency_seconds",
            &[
                ("role", "unit-test-ex".into()),
                ("shard", "0".into()),
                ("replica", "0".into()),
            ],
            0xabcd,
            0.002,
        );
        let text = render();
        let line = text
            .lines()
            .find(|l| l.contains("role=\"unit-test-ex\"") && l.contains(" # {trace_id="))
            .expect("exemplar rendered");
        // Attached to the first bucket that holds 2ms (the 10ms bound).
        assert!(line.contains("le=\"0.01\""), "{line}");
        assert!(line.contains("trace_id=\"000000000000abcd\""), "{line}");
        // The exposition still parses and the exemplar never leaks into
        // the aggregated cluster view.
        let samples = parse_exposition(&text).expect("exposition with exemplars parses");
        assert!(samples.iter().any(|s| s.label("role") == Some("unit-test-ex")));
        let merged = aggregate(&[("127.0.0.1:1".to_string(), text)]);
        assert!(!merged.contains("trace_id="), "exemplar leaked into /cluster");
        parse_exposition(&merged).expect("merged view parses");
    }

    #[test]
    fn health_body_degrades_on_bound_and_prunes_dead_probes() {
        // The probes live in the alert engine's source registry now;
        // serialize against the alerts tests that clear() it.
        let _g = crate::alerts::test_lock();
        // A deliberately huge value + bound so concurrently running tests
        // with real (small) scatter lags can never trip this bound.
        let owner = Arc::new(AtomicU64::new(3_000_000_000_000));
        let weak = Arc::downgrade(&owner);
        register_health(
            "scatter_lag_records",
            "unit-test shard=9".into(),
            Box::new(move || weak.upgrade().map(|a| a.load(Ordering::Relaxed) as f64)),
        );
        // No bound configured: this probe cannot degrade health.
        set_health_bound("scatter_lag_records", None);
        assert!(!health_body().contains("unit-test shard=9"));
        // Bound below the probe's value: degraded, with the reason.
        set_health_bound("scatter_lag_records", Some(2_000_000_000_000.0));
        let body = health_body();
        assert!(body.starts_with("degraded: "), "{body}");
        assert!(
            body.contains("scatter lag 3000000000000 > 2000000000000 (unit-test shard=9)"),
            "{body}"
        );
        // Owner drops: the probe prunes and its reason disappears.
        drop(owner);
        assert!(!health_body().contains("unit-test shard=9"));
        set_health_bound("scatter_lag_records", None);
    }

    #[test]
    #[should_panic(expected = "not declared in HEALTH_PROBES")]
    fn undeclared_health_probe_panics() {
        register_health("made_up_probe", String::new(), Box::new(|| None));
    }

    #[test]
    fn aggregate_tags_instances_and_keeps_headers_unique() {
        let a = concat!(
            "# HELP weips_wal_appends_total x\n",
            "# TYPE weips_wal_appends_total counter\n",
            "weips_wal_appends_total{role=\"master\"} 10\n"
        );
        let b = "weips_wal_appends_total{role=\"master\"} 20\nweips_bogus_total 5\n";
        let merged = aggregate(&[
            ("127.0.0.1:9001".to_string(), a.to_string()),
            ("127.0.0.1:9002".to_string(), b.to_string()),
        ]);
        assert_eq!(merged.matches("# TYPE weips_wal_appends_total counter").count(), 1);
        assert!(merged
            .contains("weips_wal_appends_total{instance=\"127.0.0.1:9001\",role=\"master\"} 10"));
        assert!(merged
            .contains("weips_wal_appends_total{instance=\"127.0.0.1:9002\",role=\"master\"} 20"));
        assert!(!merged.contains("weips_bogus_total"), "undeclared series dropped");
        let samples = parse_exposition(&merged).unwrap();
        assert!(samples.iter().all(|s| s.label("instance").is_some()));
    }
}
