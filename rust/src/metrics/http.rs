//! The `/metrics` exposition endpoint: a tiny single-threaded HTTP/1.0
//! server on the same epoll substrate the RPC layer uses
//! ([`crate::util::sys`]), plus the blocking [`http_get`] client the
//! scheduler's cluster aggregation and the tests scrape with.
//!
//! Scrapes are rare (seconds apart) and tiny (one rendered registry), so
//! unlike [`crate::net::RpcServer`] there is no handler pool: the poll
//! thread accepts, reads the request head, writes the response and closes.
//! Between scrapes the thread sleeps in `epoll_wait` on the listener plus
//! an eventfd shutdown waker — zero wakeups while idle, matching the
//! event-driven ingest design (DESIGN.md §4). On targets without epoll it
//! degrades to a 25 ms non-blocking accept sweep.
//!
//! Routes:
//! * `GET /metrics` — Prometheus text exposition of the global registry.
//! * `GET /healthz` — readiness probe: `ok`, or `degraded: <reasons>`
//!   when a bounded probe trips ([`super::health_body`]). Always HTTP
//!   200, so status-code liveness checks still pass on a stale replica.
//! * `GET /cluster` — scrape every configured peer target and merge the
//!   expositions with per-`instance` labels ([`super::aggregate`]); the
//!   scheduler serves the cluster-wide view this way. A target that is
//!   this server itself is rendered in-process (scraping yourself over a
//!   single-threaded loop would deadlock).
//! * `GET /trace` / `GET /trace/<hex id>` — recent sampled update-journey
//!   trace chains as JSON ([`crate::trace`]).
//! * `GET /alerts` / `GET /events` — the alert engine's last evaluation
//!   and the newest structured journal events as JSON
//!   ([`crate::alerts`]).
//! * `GET /cluster/alerts` / `GET /cluster/events` — the same, fetched
//!   from every configured peer target and merged per-`instance` (the
//!   `/cluster`-style fleet view `weips top` renders).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::sys;

/// How long one scrape connection may take to send its request head or
/// absorb the response before the server gives up on it.
const IO_TIMEOUT: Duration = Duration::from_millis(1000);

/// Running metrics endpoint; dropping it stops and joins the serve thread.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    waker: Option<Arc<sys::EventFd>>,
    targets: Arc<Mutex<Vec<String>>>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (port 0 = ephemeral) and serve the global registry.
    pub fn serve(addr: &str) -> std::io::Result<MetricsServer> {
        Self::serve_with_targets(addr, Vec::new())
    }

    /// [`Self::serve`] with peer `host:port` targets for `/cluster`
    /// aggregation (the scheduler role passes every role's endpoint).
    pub fn serve_with_targets(
        addr: &str,
        targets: Vec<String>,
    ) -> std::io::Result<MetricsServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let targets = Arc::new(Mutex::new(targets));
        // Event-driven idle needs both an epoll instance and a waker;
        // anything short of that falls back to the portable sweep.
        let (epoll, waker) = match (sys::Epoll::new(), sys::EventFd::new()) {
            (Ok(e), Ok(w)) => (Some(e), Some(Arc::new(w))),
            _ => (None, None),
        };
        let thread = {
            let stop = stop.clone();
            let targets = targets.clone();
            let waker = waker.clone();
            std::thread::Builder::new()
                .name(format!("metrics-{}", local.port()))
                .spawn(move || match (epoll, waker) {
                    (Some(e), Some(w)) => Self::event_loop(listener, local, stop, targets, e, w),
                    _ => Self::sweep_loop(listener, local, stop, targets),
                })?
        };
        Ok(MetricsServer { addr: local, stop, waker, targets, thread: Some(thread) })
    }

    /// Bound address (resolves ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Replace the `/cluster` aggregation targets.
    pub fn set_targets(&self, targets: Vec<String>) {
        *self.targets.lock().unwrap() = targets;
    }

    fn event_loop(
        listener: TcpListener,
        local: SocketAddr,
        stop: Arc<AtomicBool>,
        targets: Arc<Mutex<Vec<String>>>,
        epoll: sys::Epoll,
        waker: Arc<sys::EventFd>,
    ) {
        const TOKEN_ACCEPT: u64 = u64::MAX;
        const TOKEN_WAKE: u64 = u64::MAX - 1;
        if epoll.add(listener.as_raw_fd(), TOKEN_ACCEPT).is_err() {
            return Self::sweep_loop(listener, local, stop, targets);
        }
        let _ = epoll.add(waker.raw_fd(), TOKEN_WAKE);
        let mut events = [sys::EpollEvent::default(); 8];
        while !stop.load(Ordering::Acquire) {
            // The waker bounds shutdown latency; the timeout is a belt-and-
            // suspenders backstop against a lost signal.
            let n = match epoll.wait(&mut events, 1000) {
                Ok(n) => n,
                Err(_) => break,
            };
            let mut accept = false;
            for ev in events.iter().take(n) {
                match ev.token() {
                    TOKEN_WAKE => waker.drain(),
                    _ => accept = true,
                }
            }
            if accept {
                Self::accept_ready(&listener, local, &targets);
            }
        }
    }

    fn sweep_loop(
        listener: TcpListener,
        local: SocketAddr,
        stop: Arc<AtomicBool>,
        targets: Arc<Mutex<Vec<String>>>,
    ) {
        while !stop.load(Ordering::Acquire) {
            if !Self::accept_ready(&listener, local, &targets) {
                std::thread::sleep(Duration::from_millis(25));
            }
        }
    }

    /// Accept and serve everything currently pending; false when the
    /// backlog was empty.
    fn accept_ready(
        listener: &TcpListener,
        local: SocketAddr,
        targets: &Mutex<Vec<String>>,
    ) -> bool {
        let mut any = false;
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    any = true;
                    Self::handle(stream, local, targets);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(_) => break,
            }
        }
        any
    }

    fn handle(mut stream: TcpStream, local: SocketAddr, targets: &Mutex<Vec<String>>) {
        let _ = stream.set_nonblocking(false);
        let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
        let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
        let _ = stream.set_nodelay(true);
        let mut head = Vec::with_capacity(512);
        let mut buf = [0u8; 1024];
        loop {
            match stream.read(&mut buf) {
                Ok(0) => break,
                Ok(n) => {
                    head.extend_from_slice(&buf[..n]);
                    if head.windows(4).any(|w| w == b"\r\n\r\n") || head.len() > 16 * 1024 {
                        break;
                    }
                }
                Err(_) => return,
            }
        }
        let request = String::from_utf8_lossy(&head);
        let path = request
            .lines()
            .next()
            .and_then(|l| l.split_whitespace().nth(1))
            .map(|p| p.split('?').next().unwrap_or(p).to_string())
            .unwrap_or_default();
        let (status, body, json) = match path.as_str() {
            "/metrics" => ("200 OK", super::render(), false),
            // Readiness: stays HTTP 200 either way (liveness probes keep
            // passing); the body distinguishes `ok` from `degraded: ...`
            // when a bounded probe (scatter lag, WAL unsynced) trips.
            "/healthz" => ("200 OK", super::health_body(), false),
            "/cluster" => {
                let targets = targets.lock().unwrap().clone();
                if targets.is_empty() {
                    ("404 Not Found", "no cluster targets configured\n".to_string(), false)
                } else {
                    ("200 OK", scrape_targets(&targets, local), false)
                }
            }
            "/alerts" => ("200 OK", crate::alerts::render_alerts_json(), true),
            "/events" => ("200 OK", crate::alerts::render_events_json(EVENTS_LIMIT), true),
            "/cluster/alerts" | "/cluster/events" => {
                let targets = targets.lock().unwrap().clone();
                if targets.is_empty() {
                    ("404 Not Found", "no cluster targets configured\n".to_string(), false)
                } else {
                    let sub = &path["/cluster".len()..];
                    ("200 OK", merge_json_targets(&targets, local, sub), true)
                }
            }
            "/trace" => ("200 OK", crate::trace::render_recent_json(32), true),
            p if p.starts_with("/trace/") => {
                match crate::trace::parse_id(&p["/trace/".len()..])
                    .and_then(crate::trace::render_trace_json)
                {
                    Some(body) => ("200 OK", body, true),
                    None => ("404 Not Found", "trace not found\n".to_string(), false),
                }
            }
            _ => ("404 Not Found", "not found\n".to_string(), false),
        };
        let content_type = if json {
            "application/json; charset=utf-8"
        } else {
            "text/plain; version=0.0.4; charset=utf-8"
        };
        let response = format!(
            "HTTP/1.0 {status}\r\nContent-Type: {content_type}\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        );
        let _ = stream.write_all(response.as_bytes());
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(w) = &self.waker {
            w.signal();
        }
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

/// Scrape every target's `/metrics` and merge them with `instance`
/// labels. A target that resolves to the serving endpoint itself is
/// rendered in-process instead of scraped over the loopback (the serve
/// loop is single-threaded, so a self-scrape would wait on itself).
fn scrape_targets(targets: &[String], local: SocketAddr) -> String {
    let mut scrapes = Vec::with_capacity(targets.len());
    for t in targets {
        let body = if is_self(t, local) {
            super::render()
        } else {
            match http_get(t, "/metrics", IO_TIMEOUT) {
                Ok(b) => b,
                // Keep the merged view useful when one role is down: the
                // dead instance simply contributes no samples.
                Err(_) => String::new(),
            }
        };
        scrapes.push((t.clone(), body));
    }
    super::aggregate(&scrapes)
}

/// How many journal events `/events` returns per instance.
const EVENTS_LIMIT: usize = 64;

/// Fleet merge for the JSON endpoints: fetch `path` (`/alerts` or
/// `/events`) from every target and wrap the bodies per instance as
/// `{"instances":[{"instance":"host:port","data":{...}}, ...]}`. Like
/// [`scrape_targets`], a self target renders in-process and a dead
/// target is skipped rather than failing the whole view.
fn merge_json_targets(targets: &[String], local: SocketAddr, path: &str) -> String {
    let mut parts = Vec::with_capacity(targets.len());
    for t in targets {
        let body = if is_self(t, local) {
            match path {
                "/alerts" => crate::alerts::render_alerts_json(),
                _ => crate::alerts::render_events_json(EVENTS_LIMIT),
            }
        } else {
            match http_get(t, path, IO_TIMEOUT) {
                Ok(b) => b,
                Err(_) => continue,
            }
        };
        parts.push(format!(
            "{{\"instance\":\"{}\",\"data\":{}}}",
            t.replace('"', ""),
            body.trim()
        ));
    }
    format!("{{\"instances\":[{}]}}", parts.join(","))
}

fn is_self(target: &str, local: SocketAddr) -> bool {
    target
        .to_socket_addrs()
        .map(|mut addrs| {
            addrs.any(|a| {
                a.port() == local.port()
                    && (a.ip() == local.ip() || local.ip().is_unspecified())
            })
        })
        .unwrap_or(false)
}

/// Minimal blocking HTTP/1.0 GET returning the response body; errors on
/// connect/read failure or any non-200 status.
pub fn http_get(addr: &str, path: &str, timeout: Duration) -> std::io::Result<String> {
    let sock = addr
        .to_socket_addrs()?
        .next()
        .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::NotFound, "unresolvable addr"))?;
    let mut stream = TcpStream::connect_timeout(&sock, timeout)?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    stream.write_all(
        format!("GET {path} HTTP/1.0\r\nHost: {addr}\r\nConnection: close\r\n\r\n").as_bytes(),
    )?;
    let mut response = String::new();
    stream.read_to_string(&mut response)?;
    let (head, body) = response
        .split_once("\r\n\r\n")
        .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::InvalidData, "no header end"))?;
    let status = head.lines().next().unwrap_or("");
    if !status.contains(" 200") {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("{addr}{path}: {status}"),
        ));
    }
    Ok(body.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    #[test]
    fn serve_and_scrape_roundtrip() {
        let c = crate::metrics::counter(
            "weips_master_pulls_total",
            &[("role", "http-test".into()), ("shard", "0".into())],
        );
        c.fetch_add(3, Ordering::Relaxed);
        let server = MetricsServer::serve("127.0.0.1:0").unwrap();
        let addr = server.addr().to_string();
        let body = http_get(&addr, "/metrics", Duration::from_secs(2)).unwrap();
        assert!(body.contains("# TYPE weips_master_pulls_total counter"), "{body}");
        assert!(body.contains("weips_master_pulls_total{role=\"http-test\",shard=\"0\"}"));
        crate::metrics::parse_exposition(&body).expect("scrape parses");
        assert_eq!(http_get(&addr, "/healthz", Duration::from_secs(2)).unwrap(), "ok\n");
        assert!(http_get(&addr, "/nope", Duration::from_secs(2)).is_err(), "404 errors");
    }

    #[test]
    fn sequential_scrapes_reuse_the_endpoint() {
        let server = MetricsServer::serve("127.0.0.1:0").unwrap();
        let addr = server.addr().to_string();
        for _ in 0..3 {
            let body = http_get(&addr, "/metrics", Duration::from_secs(2)).unwrap();
            assert!(body.contains("# TYPE weips_routing_epoch gauge"));
        }
    }

    #[test]
    fn cluster_view_merges_with_instance_labels_and_self_scrape() {
        crate::metrics::counter(
            "weips_wal_appends_total",
            &[("role", "http-cluster-test".into())],
        )
        .fetch_add(1, Ordering::Relaxed);
        let peer = MetricsServer::serve("127.0.0.1:0").unwrap();
        let agg = MetricsServer::serve("127.0.0.1:0").unwrap();
        // Targets include the aggregator itself: exercised via the
        // in-process self-scrape path, not a loopback connection.
        agg.set_targets(vec![peer.addr().to_string(), agg.addr().to_string()]);
        let body =
            http_get(&agg.addr().to_string(), "/cluster", Duration::from_secs(4)).unwrap();
        let samples = crate::metrics::parse_exposition(&body).unwrap();
        let instances: std::collections::BTreeSet<_> = samples
            .iter()
            .filter(|s| s.name == "weips_wal_appends_total")
            .filter_map(|s| s.label("instance").map(str::to_string))
            .collect();
        assert!(
            instances.contains(&peer.addr().to_string())
                && instances.contains(&agg.addr().to_string()),
            "both instances present: {instances:?}"
        );
        assert_eq!(body.matches("# TYPE weips_wal_appends_total counter").count(), 1);
    }

    #[test]
    fn trace_routes_serve_recent_chains_and_404_unknown_ids() {
        let _g = crate::trace::test_lock().lock().unwrap();
        let id = crate::trace::trace_id("http-trace-test", "emb", 0, 8);
        crate::trace::record_stage(
            id,
            "queue_append",
            "master",
            "shard=0".into(),
            10,
            500,
            1234,
            8,
            0,
        );
        let server = MetricsServer::serve("127.0.0.1:0").unwrap();
        let addr = server.addr().to_string();
        let listing = http_get(&addr, "/trace", Duration::from_secs(2)).unwrap();
        let j = crate::util::json::Json::parse(&listing).expect("listing is JSON");
        assert!(j.get("traces").unwrap().as_arr().is_some());
        let one = http_get(
            &addr,
            &format!("/trace/{}", crate::trace::format_id(id)),
            Duration::from_secs(2),
        )
        .unwrap();
        let j = crate::util::json::Json::parse(&one).expect("chain is JSON");
        assert_eq!(
            j.get("trace_id").unwrap().as_str(),
            Some(crate::trace::format_id(id).as_str())
        );
        assert_eq!(
            j.get("spans").unwrap().as_arr().unwrap()[0].get("stage").unwrap().as_str(),
            Some("queue_append")
        );
        // Unknown and malformed ids 404 (http_get errors on non-200).
        assert!(http_get(&addr, "/trace/ffffffffffffffff", Duration::from_secs(2)).is_err());
        assert!(http_get(&addr, "/trace/not-hex", Duration::from_secs(2)).is_err());
    }

    #[test]
    fn cluster_without_targets_is_404() {
        let server = MetricsServer::serve("127.0.0.1:0").unwrap();
        let addr = server.addr().to_string();
        assert!(http_get(&addr, "/cluster", Duration::from_secs(2)).is_err());
        assert!(http_get(&addr, "/cluster/alerts", Duration::from_secs(2)).is_err());
        assert!(http_get(&addr, "/cluster/events", Duration::from_secs(2)).is_err());
    }

    #[test]
    fn alert_and_event_routes_serve_json_and_cluster_merge() {
        let _g = crate::alerts::test_lock();
        crate::alerts::clear();
        crate::alerts::evaluate("http-test");
        crate::alerts::journal("checkpoint", "http-test-ckpt", "v=1", 0);
        let peer = MetricsServer::serve("127.0.0.1:0").unwrap();
        let agg = MetricsServer::serve("127.0.0.1:0").unwrap();
        agg.set_targets(vec![peer.addr().to_string(), agg.addr().to_string()]);
        let addr = agg.addr().to_string();

        let alerts = http_get(&addr, "/alerts", Duration::from_secs(2)).unwrap();
        let j = crate::util::json::Json::parse(&alerts).expect("alerts is JSON");
        let rules = j.get("rules").unwrap().as_arr().unwrap();
        assert_eq!(rules.len(), crate::alerts::RULES.len());

        let events = http_get(&addr, "/events", Duration::from_secs(2)).unwrap();
        let j = crate::util::json::Json::parse(&events).expect("events is JSON");
        let evs = j.get("events").unwrap().as_arr().unwrap();
        assert!(
            evs.iter().any(|e| e.get("name").unwrap().as_str() == Some("http-test-ckpt")),
            "journaled event served: {events}"
        );

        // Fleet merge: one entry per live target (self via in-process).
        for sub in ["/cluster/alerts", "/cluster/events"] {
            let merged = http_get(&addr, sub, Duration::from_secs(4)).unwrap();
            let j = crate::util::json::Json::parse(&merged).expect("merge is JSON");
            let instances = j.get("instances").unwrap().as_arr().unwrap();
            assert_eq!(instances.len(), 2, "{sub}: {merged}");
            assert!(instances
                .iter()
                .all(|i| i.get("data").unwrap().as_obj().is_some()));
        }
        crate::alerts::clear();
    }
}
