//! Domino downgrade (§4.3.2): automatic version rollback.
//!
//! "The downgrade here refers to recover the model to the previous latest
//! stable version when the model occurs an abnormal change." Split exactly
//! as the paper does:
//!
//! - **trigger**: a [`Trigger`](crate::monitor::Trigger) watches the
//!   windowed business metric (plain or smoothed threshold);
//! - **execution**: pick a target version by strategy (latest stable /
//!   optimal metric), hot-switch the serving version, and resume streaming
//!   from the queue offsets recorded in that version's checkpoint
//!   manifest.
//!
//! The [`VersionManager`] is the bookkeeping half: which versions exist,
//! which are marked stable, what the current serving version is. The
//! actual state movement (master reload + slave full-sync + scatter seek)
//! is performed by the coordinator through [`DowngradePlan`].

use std::sync::Mutex;

use crate::monitor::Trigger;
use crate::storage::{CheckpointStore, CkptManifest};
use crate::{Error, Result};

/// How the execution phase picks the rollback target (§4.3.2b: "the latest
/// version strategy and the optimal index version strategy").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SwitchStrategy {
    /// Most recent version older than the failing one.
    LatestStable,
    /// Version with the best recorded business metric.
    OptimalMetric,
}

/// Everything the coordinator needs to execute a downgrade.
#[derive(Debug, Clone, PartialEq)]
pub struct DowngradePlan {
    /// Version being rolled back *from*.
    pub from_version: u64,
    /// Target version to load.
    pub target_version: u64,
    /// Queue offsets stored in the target's checkpoint (replay start).
    pub queue_offsets: Vec<u64>,
    /// Target's recorded metric (for logs).
    pub target_metric: f64,
}

/// Version bookkeeping for one model.
pub struct VersionManager {
    model: String,
    state: Mutex<VmState>,
}

struct VmState {
    current: u64,
    /// Versions explicitly marked bad (never roll back onto these).
    quarantined: Vec<u64>,
    /// The last version a downgrade landed on (drives the domino cascade:
    /// a re-fire while still serving it quarantines it and falls further).
    last_rollback: Option<u64>,
}

impl VersionManager {
    /// Manager for `model`, serving `current` initially (0 = none).
    pub fn new(model: &str, current: u64) -> VersionManager {
        VersionManager {
            model: model.to_string(),
            state: Mutex::new(VmState { current, quarantined: Vec::new(), last_rollback: None }),
        }
    }

    /// Currently served version.
    pub fn current(&self) -> u64 {
        self.state.lock().unwrap().current
    }

    /// Record that a new checkpoint version is now being served.
    pub fn advance(&self, version: u64) {
        let mut s = self.state.lock().unwrap();
        if version > s.current {
            s.current = version;
        }
    }

    /// Mark a version as bad (the one we downgraded away from).
    pub fn quarantine(&self, version: u64) {
        let mut s = self.state.lock().unwrap();
        if !s.quarantined.contains(&version) {
            s.quarantined.push(version);
        }
    }

    /// True when the version is quarantined.
    pub fn is_quarantined(&self, version: u64) -> bool {
        self.state.lock().unwrap().quarantined.contains(&version)
    }

    /// Candidate rollback versions: finalized, `<= upto`, not quarantined;
    /// newest first.
    pub fn candidates(&self, store: &CheckpointStore, upto: u64) -> Vec<CkptManifest> {
        let s = self.state.lock().unwrap();
        let mut out: Vec<CkptManifest> = store
            .list_versions(&self.model)
            .into_iter()
            .filter(|v| *v <= upto && !s.quarantined.contains(v))
            .filter_map(|v| store.load_manifest(&self.model, v).ok())
            .collect();
        out.sort_by(|a, b| b.version.cmp(&a.version));
        out
    }

    /// Build a downgrade plan by strategy.
    ///
    /// Rolling back *onto the currently served checkpoint* is legal — the
    /// common failure is live streaming drift past a healthy checkpoint.
    /// The domino cascade: if the trigger fires again while already serving
    /// a rollback target, that version is itself quarantined and the next
    /// older candidate is chosen. Errors when nothing is left to roll to.
    pub fn plan(
        &self,
        store: &CheckpointStore,
        strategy: SwitchStrategy,
    ) -> Result<DowngradePlan> {
        let from = self.current();
        // Domino step: a repeat fire on the version we already rolled onto
        // condemns that version too.
        {
            let mut s = self.state.lock().unwrap();
            if s.last_rollback == Some(s.current) && !s.quarantined.contains(&s.current) {
                let v = s.current;
                s.quarantined.push(v);
            }
        }
        let candidates = self.candidates(store, from);
        let target = match strategy {
            SwitchStrategy::LatestStable => candidates.first(),
            SwitchStrategy::OptimalMetric => candidates
                .iter()
                .max_by(|a, b| a.metric.partial_cmp(&b.metric).unwrap_or(std::cmp::Ordering::Equal)),
        };
        let target = target.ok_or_else(|| {
            Error::State(format!("no rollback candidate at or below v{from} for {}", self.model))
        })?;
        Ok(DowngradePlan {
            from_version: from,
            target_version: target.version,
            queue_offsets: target.queue_offsets.clone(),
            target_metric: target.metric,
        })
    }

    /// Commit a completed downgrade: current = target; every version newer
    /// than the target is lineage-suspect and quarantined.
    pub fn commit(&self, plan: &DowngradePlan) {
        let mut s = self.state.lock().unwrap();
        if plan.from_version > plan.target_version
            && !s.quarantined.contains(&plan.from_version)
        {
            s.quarantined.push(plan.from_version);
        }
        s.current = plan.target_version;
        s.last_rollback = Some(plan.target_version);
    }
}

/// Trigger + strategy bundle driven by the coordinator's metric loop.
pub struct Domino {
    trigger: Box<dyn Trigger>,
    pub strategy: SwitchStrategy,
    /// Suppress re-triggering for this many observations after a fire.
    cooldown: usize,
    remaining_cooldown: usize,
    pub fires: u64,
}

impl Domino {
    /// New domino controller.
    pub fn new(trigger: Box<dyn Trigger>, strategy: SwitchStrategy, cooldown: usize) -> Domino {
        Domino { trigger, strategy, cooldown, remaining_cooldown: 0, fires: 0 }
    }

    /// Feed a metric point; true when a downgrade should execute now.
    pub fn observe(&mut self, metric: f64) -> bool {
        if self.remaining_cooldown > 0 {
            self.remaining_cooldown -= 1;
            // Still feed the trigger so its window stays warm.
            let _ = self.trigger.observe(metric);
            return false;
        }
        if self.trigger.observe(metric) {
            self.fires += 1;
            self.remaining_cooldown = self.cooldown;
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::monitor::{PlainThreshold, SmoothedThreshold};
    use crate::storage::CkptManifest;

    fn store_with_versions(metrics: &[(u64, f64)]) -> (CheckpointStore, std::path::PathBuf) {
        let base = std::env::temp_dir().join(format!(
            "weips-dg-{}-{:x}",
            std::process::id(),
            crate::util::mono_ns()
        ));
        let store = CheckpointStore::new(base.join("local"), None);
        for (v, metric) in metrics {
            store.save_shard("ctr", *v, 0, b"state").unwrap();
            store
                .write_manifest(&CkptManifest {
                    model: "ctr".into(),
                    version: *v,
                    created_ms: *v * 1000,
                    num_shards: 1,
                    queue_offsets: vec![*v * 10],
                    metric: *metric,
                    kind: crate::storage::CkptKind::Base,
                    parent: 0,
                    epochs: vec![*v],
                    wal_offsets: vec![],
                    route_epoch: 0,
                    slot_map: vec![],
                })
                .unwrap();
        }
        (store, base)
    }

    #[test]
    fn latest_stable_picks_newest_older() {
        let (store, base) = store_with_versions(&[(1, 0.70), (2, 0.74), (3, 0.72)]);
        let vm = VersionManager::new("ctr", 4);
        let plan = vm.plan(&store, SwitchStrategy::LatestStable).unwrap();
        assert_eq!(plan.target_version, 3);
        assert_eq!(plan.queue_offsets, vec![30]);
        std::fs::remove_dir_all(base).ok();
    }

    #[test]
    fn optimal_metric_picks_best() {
        let (store, base) = store_with_versions(&[(1, 0.70), (2, 0.74), (3, 0.72)]);
        let vm = VersionManager::new("ctr", 4);
        let plan = vm.plan(&store, SwitchStrategy::OptimalMetric).unwrap();
        assert_eq!(plan.target_version, 2);
        assert!((plan.target_metric - 0.74).abs() < 1e-9);
        std::fs::remove_dir_all(base).ok();
    }

    #[test]
    fn quarantined_versions_skipped() {
        let (store, base) = store_with_versions(&[(1, 0.70), (2, 0.74), (3, 0.72)]);
        let vm = VersionManager::new("ctr", 4);
        vm.quarantine(3);
        let plan = vm.plan(&store, SwitchStrategy::LatestStable).unwrap();
        assert_eq!(plan.target_version, 2);
        std::fs::remove_dir_all(base).ok();
    }

    #[test]
    fn commit_quarantines_source_and_switches() {
        let (store, base) = store_with_versions(&[(1, 0.70), (2, 0.74)]);
        let vm = VersionManager::new("ctr", 3);
        let plan = vm.plan(&store, SwitchStrategy::LatestStable).unwrap();
        vm.commit(&plan);
        assert_eq!(vm.current(), 2);
        assert!(vm.is_quarantined(3));
        // Next downgrade from v2 lands on v1.
        let plan2 = vm.plan(&store, SwitchStrategy::LatestStable).unwrap();
        assert_eq!(plan2.target_version, 1);
        std::fs::remove_dir_all(base).ok();
    }

    #[test]
    fn no_candidates_is_error() {
        let (store, base) = store_with_versions(&[]);
        let vm = VersionManager::new("ctr", 1);
        assert!(vm.plan(&store, SwitchStrategy::LatestStable).is_err());
        std::fs::remove_dir_all(base).ok();
    }

    #[test]
    fn advance_is_monotonic() {
        let vm = VersionManager::new("ctr", 5);
        vm.advance(7);
        vm.advance(6); // stale advance ignored
        assert_eq!(vm.current(), 7);
    }

    #[test]
    fn domino_cooldown_prevents_thrash() {
        let mut d = Domino::new(Box::new(PlainThreshold { threshold: 0.7 }), SwitchStrategy::LatestStable, 3);
        assert!(d.observe(0.5)); // fires
        assert!(!d.observe(0.5)); // cooldown
        assert!(!d.observe(0.5));
        assert!(!d.observe(0.5));
        assert!(d.observe(0.5)); // cooldown over, still bad -> fires again
        assert_eq!(d.fires, 2);
    }

    #[test]
    fn domino_with_smoothed_trigger_end_to_end() {
        let mut d = Domino::new(
            Box::new(SmoothedThreshold::new(0.7, 3)),
            SwitchStrategy::OptimalMetric,
            0,
        );
        // Noise: no fire.
        for v in [0.72, 0.65, 0.73, 0.66, 0.74] {
            assert!(!d.observe(v));
        }
        // Regime change: fires after 3 consecutive bad points.
        assert!(!d.observe(0.6));
        assert!(!d.observe(0.59));
        assert!(d.observe(0.58));
    }

    #[test]
    fn domino_cooldown_keeps_smoothed_window_warm() {
        // Cooldown observations still feed the trigger, so the smoothed
        // window is already full when the cooldown expires: a sustained
        // regression re-fires on the very first armed observation.
        let mut d = Domino::new(
            Box::new(SmoothedThreshold::new(0.7, 3)),
            SwitchStrategy::LatestStable,
            2,
        );
        for v in [0.6, 0.59, 0.58] {
            let fired = d.observe(v);
            assert_eq!(fired, v == 0.58, "fires exactly on the 3rd dip");
        }
        assert!(!d.observe(0.57)); // cooldown 1
        assert!(!d.observe(0.56)); // cooldown 2
        assert!(d.observe(0.55), "window stayed warm through cooldown");
        assert_eq!(d.fires, 2);
    }

    #[test]
    fn domino_cooldown_rearms_clean_after_recovery() {
        // Recovery during cooldown must not leave a stale dip window
        // that fires spuriously once the cooldown expires.
        let mut d = Domino::new(
            Box::new(SmoothedThreshold::new(0.7, 3)),
            SwitchStrategy::LatestStable,
            2,
        );
        for v in [0.6, 0.59, 0.58] {
            let _ = d.observe(v);
        }
        assert_eq!(d.fires, 1);
        assert!(!d.observe(0.9)); // cooldown 1, metric recovered
        assert!(!d.observe(0.9)); // cooldown 2
        assert!(!d.observe(0.6), "recovered points inside window veto a fire");
        assert!(!d.observe(0.6));
        assert!(d.observe(0.6), "fires only after k fresh consecutive dips");
    }

    #[test]
    fn domino_ignores_nan_metric_points() {
        let mut d = Domino::new(
            Box::new(PlainThreshold { threshold: 0.7 }),
            SwitchStrategy::LatestStable,
            0,
        );
        assert!(!d.observe(f64::NAN), "NaN never fires a rollback");
        assert_eq!(d.fires, 0);
        assert!(d.observe(0.1), "trigger still live after NaN");
    }

    #[test]
    fn repeat_fire_cascades_quarantine_down_the_version_chain() {
        // The domino cascade: committing onto v2 and firing again while
        // still serving v2 condemns v2 itself and falls through to v1.
        let (store, base) = store_with_versions(&[(1, 0.70), (2, 0.74)]);
        let vm = VersionManager::new("ctr", 3);
        let plan = vm.plan(&store, SwitchStrategy::LatestStable).unwrap();
        assert_eq!(plan.target_version, 2);
        vm.commit(&plan);
        let plan2 = vm.plan(&store, SwitchStrategy::LatestStable).unwrap();
        assert_eq!(plan2.target_version, 1, "re-fire skips the quarantined v2");
        assert!(vm.is_quarantined(2));
        vm.commit(&plan2);
        // Nothing older than v1: a third fire is a clean error, and v1
        // (now quarantined by the cascade) is never re-offered.
        assert!(vm.plan(&store, SwitchStrategy::LatestStable).is_err());
        std::fs::remove_dir_all(base).ok();
    }
}
