//! Parameter tables: sharded sparse slot-tables and dense tensors.
//!
//! A [`SparseTable`] holds the rows of one logical parameter matrix on one
//! server shard (id → `slots × dim` f32s, slot layout owned by the
//! optimizer). It implements the XDL-derived features the paper adopts
//! (§2.2, §4.1c): **feature entry filter** (rows materialize only after an
//! id has been observed `entry_threshold` times — low-frequency junk never
//! allocates) and **feature expire** (ids untouched for a TTL are evicted,
//! and the eviction propagates to slaves through sync deletes).
//!
//! [`SparseTable`] is the single-threaded building block (externally
//! locked; still used by scratch decoding and micro-benches).
//! [`StripedSparseTable`] is what the shard servers run on the hot path:
//! ids hash into N independent lock stripes, each its own
//! `RwLock<{rows, probation}>`, and every batched operation groups a
//! request's ids by stripe so each stripe lock is taken **once per batch**
//! instead of once per id. Pushes, pulls, expire passes and gather
//! snapshots touching different stripes proceed fully in parallel.
//! Lock-ordering rule: multi-stripe operations (checkpoint encode/decode)
//! acquire stripe guards in ascending stripe index; batch operations hold
//! at most one stripe lock at a time. See `DESIGN.md` §"Lock-striped
//! tables".
//!
//! Row value storage is pluggable ([`RowStore`]): the default per-stripe
//! bump **arena** keeps a stripe's rows in a few large chunks so batched
//! gathers walk contiguous memory (dead space from evictions is measured
//! as [`StripedSparseTable::arena_waste_floats`] and reclaimed when the
//! expire sweep compacts the stripe); `boxed` keeps the historical
//! one-heap-allocation-per-row layout. Both backings produce byte-
//! identical checkpoints and deltas.

use crate::codec::{Encode, Reader, Writer};
use crate::optim::Optimizer;
use crate::util::hash::{fxhash64, FxHashMap};
use crate::util::ThreadPool;
use crate::{Error, Result};
use std::ptr::NonNull;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// Stripe count used when none is configured (`WEIPS_TABLE_STRIPES`
/// overrides; the cluster config's `table_stripes` knob wins where a
/// config is present).
pub fn default_stripe_count() -> usize {
    use std::sync::OnceLock;
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("WEIPS_TABLE_STRIPES")
            .ok()
            .and_then(|v| v.parse().ok())
            .filter(|&n| n >= 1)
            .unwrap_or(8)
    })
}

/// Owning stripe for an id among `stripes` stripes. The single source of
/// truth for stripe selection: the master tables, the slave serving
/// tables and the sync collector all key on this, which is what lets the
/// collector's per-stripe queues line up with the tables' lock stripes.
/// Uses the *high* 32 bits of `fxhash64(id)` so stripe choice stays
/// independent of the shard router (which keys on the low bits).
#[inline]
pub fn stripe_of_id(id: u64, stripes: usize) -> usize {
    ((fxhash64(id) >> 32) as usize) % stripes.max(1)
}

/// One table's value snapshot: (id, full row values or `None` if absent).
pub type RowSnapshot = Vec<(u64, Option<Vec<f32>>)>;

/// One stripe's coalesced row operations for
/// [`StripedSparseTable::apply_grouped`]: `(id, Some(full row))` upserts,
/// `(id, None)` deletes, in arrival order.
pub type RowOps<'a> = Vec<(u64, Option<&'a [f32]>)>;

// ---------------------------------------------------------------------------
// Row storage: owned boxes or per-stripe bump arenas
// ---------------------------------------------------------------------------

/// Backing storage for sparse row values (the `table_row_store` knob).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RowStore {
    /// Rows bump-allocate out of per-stripe arenas: pull-path gathers
    /// walk a few large contiguous chunks instead of one heap box per
    /// row, and allocation is a cursor bump under the stripe lock the
    /// caller already holds. Space stranded by evictions/overwrites is
    /// reclaimed when the expire sweep compacts the stripe.
    Arena,
    /// One heap allocation per row (the historical layout). Frees row
    /// memory eagerly on delete/expire; useful when the working set
    /// churns much faster than the expire cadence.
    Boxed,
}

impl RowStore {
    /// The config-string name (`arena` / `boxed`) — the `store` label of
    /// the `weips_table_row_store_info` gauge.
    pub fn name(self) -> &'static str {
        match self {
            RowStore::Arena => "arena",
            RowStore::Boxed => "boxed",
        }
    }

    /// Parse a config string: `arena` | `boxed`.
    pub fn parse(s: &str) -> Result<RowStore> {
        match s {
            "arena" => Ok(RowStore::Arena),
            "boxed" => Ok(RowStore::Boxed),
            other => Err(Error::Config(format!(
                "unknown table_row_store '{other}' (expected arena|boxed)"
            ))),
        }
    }
}

/// A row's value storage: an owned heap allocation or a slice of a stripe
/// arena chunk. Behaves as `[f32]` via `Deref`/`DerefMut`; arena-backed
/// values do **not** free on drop — their memory belongs to the stripe's
/// [`Arena`] and is reclaimed wholesale by compaction or reset.
///
/// Safety discipline: an arena-backed `RowValues` is only reachable
/// through the `Stripe` that owns its arena, and every access happens
/// under that stripe's `RwLock` — the same lock that guards the arena's
/// chunk list — so the pointed-to memory cannot be freed or compacted
/// away while any reference exists. [`Clone`] always produces an owned
/// copy, so rows escaping the lock (e.g.
/// [`StripedSparseTable::get_row`]) never alias arena memory.
pub struct RowValues {
    ptr: NonNull<f32>,
    len: u32,
    owned: bool,
}

// Plain f32 payload; aliasing is governed by the owning stripe's lock
// (arena-backed) or by unique ownership (owned).
unsafe impl Send for RowValues {}
unsafe impl Sync for RowValues {}

impl RowValues {
    /// Take ownership of a heap allocation (freed on drop).
    pub fn owned(v: Vec<f32>) -> RowValues {
        let boxed = v.into_boxed_slice();
        let len = boxed.len() as u32;
        let ptr = NonNull::new(Box::into_raw(boxed) as *mut f32).expect("box is non-null");
        RowValues { ptr, len, owned: true }
    }

    /// Wrap an arena slice (not freed on drop).
    ///
    /// # Safety
    /// `ptr..ptr + len` must stay valid for as long as this value is
    /// used — upheld by the stripe-lock discipline described on the type.
    unsafe fn arena(ptr: NonNull<f32>, len: usize) -> RowValues {
        RowValues { ptr, len: len as u32, owned: false }
    }

    /// True when backed by a stripe arena (diagnostics and tests).
    pub fn is_arena_backed(&self) -> bool {
        !self.owned
    }
}

impl std::ops::Deref for RowValues {
    type Target = [f32];
    #[inline]
    fn deref(&self) -> &[f32] {
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len as usize) }
    }
}

impl std::ops::DerefMut for RowValues {
    #[inline]
    fn deref_mut(&mut self) -> &mut [f32] {
        unsafe { std::slice::from_raw_parts_mut(self.ptr.as_ptr(), self.len as usize) }
    }
}

impl Drop for RowValues {
    fn drop(&mut self) {
        if self.owned {
            unsafe {
                drop(Box::from_raw(std::ptr::slice_from_raw_parts_mut(
                    self.ptr.as_ptr(),
                    self.len as usize,
                )));
            }
        }
    }
}

impl Clone for RowValues {
    fn clone(&self) -> RowValues {
        RowValues::owned(self.to_vec())
    }
}

impl PartialEq for RowValues {
    fn eq(&self, other: &RowValues) -> bool {
        self[..] == other[..]
    }
}

impl std::fmt::Debug for RowValues {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        std::fmt::Debug::fmt(&self[..], f)
    }
}

/// Floats per arena chunk (256 KiB). Chunks are boxed slices whose heap
/// addresses never move when the chunk *list* grows, so handed-out row
/// pointers stay stable for the arena's lifetime.
const ARENA_CHUNK_FLOATS: usize = 64 * 1024;

/// Per-stripe bump allocator for row values. Rows allocate by advancing
/// a cursor in the newest chunk; nothing is freed individually — dead
/// space (evicted or re-allocated rows) is `allocated` minus live floats
/// and is reclaimed by [`Stripe::compact_arena`].
#[derive(Default)]
struct Arena {
    chunks: Vec<Box<[f32]>>,
    /// Bump cursor into the last chunk.
    used: usize,
    /// Total floats ever handed out (live rows + dead space).
    allocated: usize,
}

impl Arena {
    /// Bump-allocate `n` floats, opening a new chunk when the current
    /// one cannot fit the row.
    fn bump(&mut self, n: usize) -> &mut [f32] {
        let fits = self.chunks.last().map_or(false, |c| self.used + n <= c.len());
        if !fits {
            self.chunks.push(vec![0.0f32; ARENA_CHUNK_FLOATS.max(n)].into_boxed_slice());
            self.used = 0;
        }
        let start = self.used;
        self.used += n;
        self.allocated += n;
        let chunk = self.chunks.last_mut().expect("chunk just ensured");
        &mut chunk[start..start + n]
    }

    fn alloc_zeroed(&mut self, n: usize) -> RowValues {
        let slot = self.bump(n);
        slot.fill(0.0);
        let ptr = NonNull::new(slot.as_mut_ptr()).expect("arena slice is non-null");
        unsafe { RowValues::arena(ptr, n) }
    }

    fn alloc(&mut self, src: &[f32]) -> RowValues {
        let slot = self.bump(src.len());
        slot.copy_from_slice(src);
        let ptr = NonNull::new(slot.as_mut_ptr()).expect("arena slice is non-null");
        unsafe { RowValues::arena(ptr, src.len()) }
    }

    /// Drop every chunk. Only sound when no live row points into them
    /// (callers clear the row map first).
    fn reset(&mut self) {
        self.chunks.clear();
        self.used = 0;
        self.allocated = 0;
    }
}

/// One sparse row.
#[derive(Debug, Clone, PartialEq)]
pub struct Row {
    pub values: RowValues,
    pub last_access_ms: u64,
    pub updates: u32,
    /// Checkpoint epoch of the last **value** mutation (see
    /// [`StripedSparseTable::set_write_epoch`]). 0 = clean (restored from
    /// a checkpoint and untouched since). Not persisted in snapshots.
    pub epoch: u64,
    /// Checkpoint epoch of the last **access-time** refresh
    /// ([`StripedSparseTable::pull_slot`]). Kept separate from `epoch` so
    /// durability deltas (which take `max(epoch, access_epoch)`) preserve
    /// `last_access_ms` freshness across recovery, while migration
    /// catch-up — which only needs value exactness — tracks `epoch` alone
    /// and converges even under a pull-heavy working set.
    pub access_epoch: u64,
}

/// One row captured by a dirty-epoch delta collection
/// ([`StripedSparseTable::collect_delta`]): the full row plus the
/// metadata an incremental chunk must carry so recovery reproduces the
/// uninterrupted state byte-for-byte.
#[derive(Debug, Clone, PartialEq)]
pub struct DeltaRow {
    pub id: u64,
    pub last_access_ms: u64,
    pub updates: u32,
    pub values: Vec<f32>,
}

/// Sparse parameter table (one shard's slice of one matrix).
pub struct SparseTable {
    name: String,
    dim: usize,
    optimizer: Arc<dyn Optimizer>,
    rows: FxHashMap<u64, Row>,
    /// Entry filter: ids seen fewer than `entry_threshold` times live here.
    probation: FxHashMap<u64, u32>,
    entry_threshold: u32,
}

impl SparseTable {
    /// New table; `entry_threshold = 1` materializes rows immediately.
    pub fn new(
        name: impl Into<String>,
        dim: usize,
        optimizer: Arc<dyn Optimizer>,
        entry_threshold: u32,
    ) -> SparseTable {
        SparseTable {
            name: name.into(),
            dim,
            optimizer,
            rows: FxHashMap::default(),
            probation: FxHashMap::default(),
            entry_threshold: entry_threshold.max(1),
        }
    }

    /// Table name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Per-slot dimension.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Optimizer owning the slot layout.
    pub fn optimizer(&self) -> &Arc<dyn Optimizer> {
        &self.optimizer
    }

    /// Materialized row count.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when no rows are materialized.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Approximate bytes held (rows only).
    pub fn bytes(&self) -> usize {
        self.rows.len() * (self.optimizer.row_width(self.dim) * 4 + 24)
    }

    fn row_width(&self) -> usize {
        self.optimizer.row_width(self.dim)
    }

    /// Read one slot (by name) for `ids` into `out` (missing ids → 0.0).
    /// `out.len() == ids.len() * dim`. Updates access times.
    pub fn pull_slot(&mut self, ids: &[u64], slot: &str, now_ms: u64, out: &mut [f32]) -> Result<()> {
        let dim = self.dim;
        debug_assert_eq!(out.len(), ids.len() * dim);
        let slot_idx = self
            .optimizer
            .slot_index(slot)
            .ok_or_else(|| Error::NotFound(format!("slot {slot} in table {}", self.name)))?;
        for (i, id) in ids.iter().enumerate() {
            let dst = &mut out[i * dim..(i + 1) * dim];
            match self.rows.get_mut(id) {
                Some(row) => {
                    row.last_access_ms = now_ms;
                    dst.copy_from_slice(&row.values[slot_idx * dim..(slot_idx + 1) * dim]);
                }
                None => dst.fill(0.0),
            }
        }
        Ok(())
    }

    /// Full row for `id` (no access-time touch).
    pub fn get_row(&self, id: u64) -> Option<&Row> {
        self.rows.get(&id)
    }

    /// Apply pre-aggregated gradients: `grads.len() == ids.len() * dim`,
    /// ids must be unique (aggregate duplicates upstream — see
    /// [`aggregate_grads`]). Returns the ids whose rows changed (i.e.
    /// passed the entry filter) for the sync collector.
    pub fn apply_grads(&mut self, ids: &[u64], grads: &[f32], now_ms: u64) -> Vec<u64> {
        debug_assert_eq!(grads.len(), ids.len() * self.dim);
        let dim = self.dim;
        let width = self.row_width();
        let mut touched = Vec::with_capacity(ids.len());
        for (i, &id) in ids.iter().enumerate() {
            if !self.rows.contains_key(&id) {
                // Entry filter: count observations until the threshold.
                let seen = self.probation.entry(id).or_insert(0);
                *seen += 1;
                if *seen < self.entry_threshold {
                    continue;
                }
                self.probation.remove(&id);
                self.rows.insert(
                    id,
                    Row {
                        values: RowValues::owned(vec![0.0; width]),
                        last_access_ms: now_ms,
                        updates: 0,
                        epoch: 0,
                        access_epoch: 0,
                    },
                );
            }
            let row = self.rows.get_mut(&id).unwrap();
            row.updates += 1;
            row.last_access_ms = now_ms;
            self.optimizer
                .apply(&mut row.values, &grads[i * dim..(i + 1) * dim], dim, row.updates);
            touched.push(id);
        }
        touched
    }

    /// Run `ids` through the entry filter, materializing rows that pass.
    /// Returns the subset of `ids` (with positions) that are materialized
    /// and may be updated. Order of first occurrence is preserved.
    pub fn ensure_rows(&mut self, ids: &[u64], now_ms: u64) -> Vec<(usize, u64)> {
        let width = self.row_width();
        let mut ready = Vec::with_capacity(ids.len());
        for (pos, &id) in ids.iter().enumerate() {
            if !self.rows.contains_key(&id) {
                let seen = self.probation.entry(id).or_insert(0);
                *seen += 1;
                if *seen < self.entry_threshold {
                    continue;
                }
                self.probation.remove(&id);
                self.rows.insert(
                    id,
                    Row {
                        values: RowValues::owned(vec![0.0; width]),
                        last_access_ms: now_ms,
                        updates: 0,
                        epoch: 0,
                        access_epoch: 0,
                    },
                );
            }
            ready.push((pos, id));
        }
        ready
    }

    /// Gather two slots (by index) for materialized `ids` into flat
    /// `(a, b)` arrays of `ids.len() * dim` — the batched-FTRL read path
    /// (slots z and n). Missing rows panic (call [`Self::ensure_rows`]).
    pub fn gather_slot_pair(&self, ids: &[u64], slot_a: usize, slot_b: usize, a: &mut [f32], b: &mut [f32]) {
        let dim = self.dim;
        for (i, id) in ids.iter().enumerate() {
            let row = self.rows.get(id).expect("gather of unmaterialized row");
            a[i * dim..(i + 1) * dim]
                .copy_from_slice(&row.values[slot_a * dim..(slot_a + 1) * dim]);
            b[i * dim..(i + 1) * dim]
                .copy_from_slice(&row.values[slot_b * dim..(slot_b + 1) * dim]);
        }
    }

    /// Scatter three slots back for `ids` (batched-FTRL write path: z, n,
    /// w), bumping update counts and access times.
    pub fn scatter_slot_triple(
        &mut self,
        ids: &[u64],
        slots: (usize, usize, usize),
        a: &[f32],
        b: &[f32],
        c: &[f32],
        now_ms: u64,
    ) {
        let dim = self.dim;
        for (i, id) in ids.iter().enumerate() {
            let row = self.rows.get_mut(id).expect("scatter to unmaterialized row");
            row.values[slots.0 * dim..(slots.0 + 1) * dim]
                .copy_from_slice(&a[i * dim..(i + 1) * dim]);
            row.values[slots.1 * dim..(slots.1 + 1) * dim]
                .copy_from_slice(&b[i * dim..(i + 1) * dim]);
            row.values[slots.2 * dim..(slots.2 + 1) * dim]
                .copy_from_slice(&c[i * dim..(i + 1) * dim]);
            row.updates += 1;
            row.last_access_ms = now_ms;
        }
    }

    /// Overwrite a full row (scatter / checkpoint-load path).
    pub fn upsert_row(&mut self, id: u64, values: &[f32], now_ms: u64) -> Result<()> {
        if values.len() != self.row_width() {
            return Err(Error::Codec(format!(
                "row width {} != {} for table {}",
                values.len(),
                self.row_width(),
                self.name
            )));
        }
        match self.rows.get_mut(&id) {
            Some(row) => {
                row.values.copy_from_slice(values);
                row.last_access_ms = now_ms;
            }
            None => {
                self.rows.insert(
                    id,
                    Row {
                        values: RowValues::owned(values.to_vec()),
                        last_access_ms: now_ms,
                        updates: 0,
                        epoch: 0,
                        access_epoch: 0,
                    },
                );
            }
        }
        Ok(())
    }

    /// Remove a row; true if it existed.
    pub fn delete(&mut self, id: u64) -> bool {
        self.probation.remove(&id);
        self.rows.remove(&id).is_some()
    }

    /// Feature expire: evict rows untouched for `ttl_ms`; returns evicted
    /// ids (propagated to slaves as sync deletes).
    pub fn expire(&mut self, now_ms: u64, ttl_ms: u64) -> Vec<u64> {
        let dead: Vec<u64> = self
            .rows
            .iter()
            .filter(|(_, r)| now_ms.saturating_sub(r.last_access_ms) > ttl_ms)
            .map(|(id, _)| *id)
            .collect();
        for id in &dead {
            self.rows.remove(id);
        }
        // Probation entries also age out wholesale on expire passes.
        self.probation.clear();
        dead
    }

    /// Iterate all materialized rows.
    pub fn iter(&self) -> impl Iterator<Item = (&u64, &Row)> {
        self.rows.iter()
    }

    /// Serialize every row (checkpoint shard payload).
    pub fn encode_rows(&self, w: &mut Writer) {
        w.put_str(&self.name);
        w.put_u32(self.dim as u32);
        w.put_u32(self.row_width() as u32);
        w.put_varint(self.rows.len() as u64);
        for (id, row) in &self.rows {
            w.put_varint(*id);
            w.put_varint(row.last_access_ms);
            w.put_u32(row.updates);
            w.put_f32_slice(&row.values);
        }
    }

    /// Restore rows from a checkpoint (replaces current content).
    pub fn decode_rows(&mut self, r: &mut Reader) -> Result<()> {
        let name = r.get_str()?;
        if name != self.name {
            return Err(Error::Checkpoint(format!(
                "checkpoint table {name} != {}",
                self.name
            )));
        }
        let dim = r.get_u32()? as usize;
        let width = r.get_u32()? as usize;
        if dim != self.dim || width != self.row_width() {
            return Err(Error::Checkpoint(format!(
                "table {} schema mismatch: dim {dim}/{} width {width}/{}",
                self.name,
                self.dim,
                self.row_width()
            )));
        }
        let count = r.get_varint()? as usize;
        self.rows.clear();
        self.probation.clear();
        for _ in 0..count {
            let id = r.get_varint()?;
            let last_access_ms = r.get_varint()?;
            let updates = r.get_u32()?;
            let values = r.get_f32_slice()?;
            if values.len() != width {
                return Err(Error::Checkpoint(format!(
                    "row {id} width {} != {width}",
                    values.len()
                )));
            }
            self.rows.insert(
                id,
                Row {
                    values: RowValues::owned(values),
                    last_access_ms,
                    updates,
                    epoch: 0,
                    access_epoch: 0,
                },
            );
        }
        Ok(())
    }
}

/// Aggregate duplicate ids in a push batch by summing their gradients.
/// Returns unique ids + summed grads (order of first occurrence).
pub fn aggregate_grads(ids: &[u64], grads: &[f32], dim: usize) -> (Vec<u64>, Vec<f32>) {
    debug_assert_eq!(grads.len(), ids.len() * dim);
    let mut index: FxHashMap<u64, usize> = FxHashMap::default();
    let mut out_ids = Vec::with_capacity(ids.len());
    let mut out_grads: Vec<f32> = Vec::with_capacity(grads.len());
    for (i, &id) in ids.iter().enumerate() {
        match index.get(&id) {
            Some(&pos) => {
                let dst = pos * dim;
                for j in 0..dim {
                    out_grads[dst + j] += grads[i * dim + j];
                }
            }
            None => {
                index.insert(id, out_ids.len());
                out_ids.push(id);
                out_grads.extend_from_slice(&grads[i * dim..(i + 1) * dim]);
            }
        }
    }
    (out_ids, out_grads)
}

// ---------------------------------------------------------------------------
// Lock-striped sparse tables (the shard-server hot path)
// ---------------------------------------------------------------------------

/// One lock stripe: an independent slice of the id space with its own row
/// map, probation (entry-filter) map and implicit expire clock (the
/// per-row `last_access_ms` it guards). For incremental durability each
/// stripe also keeps its tombstones (`graves`: ids deleted since the last
/// pruned epoch) and the highest epoch any mutation in the stripe has
/// stamped, so delta collection can skip clean stripes without touching
/// their rows.
#[derive(Default)]
struct Stripe {
    rows: FxHashMap<u64, Row>,
    probation: FxHashMap<u64, u32>,
    /// id -> epoch at which the row was deleted (cleared on re-insert and
    /// by [`StripedSparseTable::prune_graves`]).
    graves: FxHashMap<u64, u64>,
    /// Highest epoch stamped by any mutation (row or grave) in this
    /// stripe; lets [`StripedSparseTable::collect_delta`] skip stripes
    /// untouched since the cut.
    max_epoch: u64,
    /// Bump arena backing this stripe's row values in
    /// [`RowStore::Arena`] mode (empty and unused in `Boxed` mode).
    arena: Arena,
}

impl Stripe {
    /// Allocate zeroed row values in the configured backing.
    fn alloc_zeroed(&mut self, store: RowStore, n: usize) -> RowValues {
        match store {
            RowStore::Arena => self.arena.alloc_zeroed(n),
            RowStore::Boxed => RowValues::owned(vec![0.0; n]),
        }
    }

    /// Allocate row values initialized from `src`.
    fn alloc_values(&mut self, store: RowStore, src: &[f32]) -> RowValues {
        match store {
            RowStore::Arena => self.arena.alloc(src),
            RowStore::Boxed => RowValues::owned(src.to_vec()),
        }
    }

    /// Adopt an already-owned vector (avoids the copy in boxed mode).
    fn adopt_values(&mut self, store: RowStore, v: Vec<f32>) -> RowValues {
        match store {
            RowStore::Arena => self.arena.alloc(&v),
            RowStore::Boxed => RowValues::owned(v),
        }
    }

    /// Rebuild the arena from live rows, dropping dead space. Row
    /// pointers are rewritten in place; runs under the stripe's write
    /// lock, so no reader can observe the old addresses.
    fn compact_arena(&mut self) {
        let mut fresh = Arena::default();
        for row in self.rows.values_mut() {
            row.values = fresh.alloc(&row.values);
        }
        self.arena = fresh;
    }
}

/// Sparse parameter table partitioned into N lock stripes.
///
/// All methods take `&self`; mutation happens under per-stripe `RwLock`s.
/// Batched APIs ([`Self::apply_batch`], [`Self::pull_slot`],
/// [`Self::read_rows`]) group ids by stripe and take each stripe lock once
/// per batch. Stripe selection uses the *high* 32 bits of `fxhash64(id)`
/// so it stays independent of the shard router (which keys on the low
/// bits): ids that landed on this shard still spread evenly over stripes
/// for any (shard count, stripe count) pair.
pub struct StripedSparseTable {
    name: String,
    dim: usize,
    optimizer: Arc<dyn Optimizer>,
    entry_threshold: u32,
    stripes: Vec<RwLock<Stripe>>,
    /// Current checkpoint write epoch: every mutation stamps the rows it
    /// touches with this value (loaded *inside* the stripe's write-lock
    /// section, so an epoch cut that happens-before a stripe scan is
    /// observed by every later writer of that stripe — see DESIGN.md §5).
    /// The shard owner bumps it at every checkpoint/WAL cut via
    /// [`Self::set_write_epoch`]; standalone tables stay at the initial 1.
    write_epoch: AtomicU64,
    /// Record tombstones on delete/expire (on by default). Deployments
    /// with no incremental consumer — full checkpoint mode, scheduler-less
    /// serving — turn this off so expired ids free *all* their memory
    /// instead of leaving grave entries no prune pass will ever drop.
    track_graves: std::sync::atomic::AtomicBool,
    /// Row value backing (fixed at construction; see [`RowStore`]).
    row_store: RowStore,
}

impl StripedSparseTable {
    /// New table with `stripes` lock stripes (min 1) and the default
    /// [`RowStore::Arena`] backing; `entry_threshold = 1` materializes
    /// rows immediately.
    pub fn new(
        name: impl Into<String>,
        dim: usize,
        optimizer: Arc<dyn Optimizer>,
        entry_threshold: u32,
        stripes: usize,
    ) -> StripedSparseTable {
        Self::with_row_store(name, dim, optimizer, entry_threshold, stripes, RowStore::Arena)
    }

    /// [`Self::new`] with an explicit row-value backing (the cluster
    /// config's `table_row_store` knob).
    pub fn with_row_store(
        name: impl Into<String>,
        dim: usize,
        optimizer: Arc<dyn Optimizer>,
        entry_threshold: u32,
        stripes: usize,
        row_store: RowStore,
    ) -> StripedSparseTable {
        let stripes = stripes.max(1);
        StripedSparseTable {
            name: name.into(),
            dim,
            optimizer,
            entry_threshold: entry_threshold.max(1),
            stripes: (0..stripes).map(|_| RwLock::new(Stripe::default())).collect(),
            write_epoch: AtomicU64::new(1),
            track_graves: std::sync::atomic::AtomicBool::new(true),
            row_store,
        }
    }

    /// Row value backing this table was built with.
    pub fn row_store(&self) -> RowStore {
        self.row_store
    }

    /// Floats resident in stripe arenas but no longer referenced by any
    /// live row (evicted or overwritten rows awaiting the next expire
    /// sweep's compaction). Always 0 in [`RowStore::Boxed`] mode.
    pub fn arena_waste_floats(&self) -> usize {
        let width = self.row_width();
        self.stripes
            .iter()
            .map(|s| {
                let s = s.read().unwrap();
                s.arena.allocated.saturating_sub(s.rows.len() * width)
            })
            .sum()
    }

    /// Enable/disable tombstone recording (see the field docs; delta
    /// collection still works when off, it just cannot propagate deletes).
    pub fn set_grave_tracking(&self, on: bool) {
        self.track_graves.store(on, Ordering::Relaxed);
    }

    /// Current write epoch (the value mutations stamp touched rows with).
    pub fn write_epoch(&self) -> u64 {
        self.write_epoch.load(Ordering::SeqCst)
    }

    /// Set the write epoch. The shard owner calls this at every
    /// checkpoint / WAL cut (all of a shard's tables move in lockstep):
    /// after the cut, a delta collection with `since = old epoch - 1`
    /// captures exactly the rows mutated since the previous cut, and no
    /// later mutation can be missed by the *next* delta because writers
    /// re-load the epoch under each stripe's write lock.
    pub fn set_write_epoch(&self, epoch: u64) {
        self.write_epoch.store(epoch, Ordering::SeqCst);
    }

    /// Table name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Per-slot dimension.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Optimizer owning the slot layout.
    pub fn optimizer(&self) -> &Arc<dyn Optimizer> {
        &self.optimizer
    }

    /// Number of lock stripes.
    pub fn stripe_count(&self) -> usize {
        self.stripes.len()
    }

    /// Owning stripe for an id.
    #[inline]
    pub fn stripe_of(&self, id: u64) -> usize {
        stripe_of_id(id, self.stripes.len())
    }

    fn row_width(&self) -> usize {
        self.optimizer.row_width(self.dim)
    }

    /// Materialized row count (sums stripes; racy under writes, exact at
    /// quiesce).
    pub fn len(&self) -> usize {
        self.stripes.iter().map(|s| s.read().unwrap().rows.len()).sum()
    }

    /// True when no rows are materialized.
    pub fn is_empty(&self) -> bool {
        self.stripes.iter().all(|s| s.read().unwrap().rows.is_empty())
    }

    /// Approximate bytes held (rows only).
    pub fn bytes(&self) -> usize {
        self.len() * (self.row_width() * 4 + 24)
    }

    /// Split `ids` into per-stripe buckets as `(positions, ids)` pairs so
    /// callers can reassemble responses in request order. Bucket index =
    /// stripe index.
    fn group_by_stripe(&self, ids: &[u64]) -> Vec<(Vec<usize>, Vec<u64>)> {
        let mut buckets: Vec<(Vec<usize>, Vec<u64>)> =
            (0..self.stripes.len()).map(|_| (Vec::new(), Vec::new())).collect();
        for (pos, &id) in ids.iter().enumerate() {
            let s = self.stripe_of(id);
            buckets[s].0.push(pos);
            buckets[s].1.push(id);
        }
        buckets
    }

    /// Read one slot (by name) for `ids` into `out` (missing ids → 0.0),
    /// one stripe write-lock per touched stripe (access times refresh).
    /// `out.len() == ids.len() * dim`.
    ///
    /// Access refreshes stamp the row's *access* epoch (at most once per
    /// row per checkpoint window; the stamp only moves when the coarse
    /// millisecond clock actually changed), so `last_access_ms` freshness
    /// survives incremental recovery and a post-recovery expire pass
    /// evicts exactly what the uninterrupted run would have. The value
    /// epoch is untouched: migration catch-up tracks values only and
    /// converges even under a pull-heavy working set.
    pub fn pull_slot(&self, ids: &[u64], slot: &str, now_ms: u64, out: &mut [f32]) -> Result<()> {
        let dim = self.dim;
        debug_assert_eq!(out.len(), ids.len() * dim);
        let slot_idx = self
            .optimizer
            .slot_index(slot)
            .ok_or_else(|| Error::NotFound(format!("slot {slot} in table {}", self.name)))?;
        for (stripe, (positions, sids)) in self.group_by_stripe(ids).into_iter().enumerate() {
            if sids.is_empty() {
                continue;
            }
            let mut s = self.stripes[stripe].write().unwrap();
            let epoch = self.write_epoch.load(Ordering::Relaxed);
            let mut access_dirty = false;
            for (&pos, id) in positions.iter().zip(&sids) {
                let dst = &mut out[pos * dim..(pos + 1) * dim];
                match s.rows.get_mut(id) {
                    Some(row) => {
                        if row.last_access_ms != now_ms {
                            row.last_access_ms = now_ms;
                            if row.access_epoch < epoch {
                                row.access_epoch = epoch;
                            }
                            access_dirty = true;
                        }
                        dst.copy_from_slice(&row.values[slot_idx * dim..(slot_idx + 1) * dim]);
                    }
                    None => dst.fill(0.0),
                }
            }
            if access_dirty {
                s.max_epoch = s.max_epoch.max(epoch);
            }
        }
        Ok(())
    }

    /// Read full rows for `ids` into `out` (missing ids → 0.0) without
    /// touching access times — the `slot == "*"` pull and snapshot read
    /// path. Takes stripe *read* locks only. `out.len() == ids.len() *
    /// row_width`.
    pub fn pull_rows(&self, ids: &[u64], out: &mut [f32]) {
        let width = self.row_width();
        debug_assert_eq!(out.len(), ids.len() * width);
        for (stripe, (positions, sids)) in self.group_by_stripe(ids).into_iter().enumerate() {
            if sids.is_empty() {
                continue;
            }
            let s = self.stripes[stripe].read().unwrap();
            for (&pos, id) in positions.iter().zip(&sids) {
                let dst = &mut out[pos * width..(pos + 1) * width];
                match s.rows.get(id) {
                    Some(row) => dst.copy_from_slice(&row.values),
                    None => dst.fill(0.0),
                }
            }
        }
    }

    /// Clone one row out (no access-time touch).
    pub fn get_row(&self, id: u64) -> Option<Row> {
        self.stripes[self.stripe_of(id)].read().unwrap().rows.get(&id).cloned()
    }

    /// Apply pre-aggregated gradients with the scalar optimizer:
    /// `grads.len() == ids.len() * dim`, ids must be unique (aggregate
    /// duplicates upstream — see [`aggregate_grads`]). One stripe
    /// write-lock per touched stripe. Returns the ids whose rows changed
    /// (passed the entry filter) for the sync collector, grouped by
    /// stripe.
    pub fn apply_batch(&self, ids: &[u64], grads: &[f32], now_ms: u64) -> Vec<u64> {
        debug_assert_eq!(grads.len(), ids.len() * self.dim);
        let dim = self.dim;
        let width = self.row_width();
        let mut touched = Vec::with_capacity(ids.len());
        for (stripe, (positions, sids)) in self.group_by_stripe(ids).into_iter().enumerate() {
            if sids.is_empty() {
                continue;
            }
            let mut s = self.stripes[stripe].write().unwrap();
            // Loaded under the stripe lock so an epoch cut ordered before
            // this lock acquisition is always observed (dirty tracking).
            let epoch = self.write_epoch.load(Ordering::Relaxed);
            let before = touched.len();
            for (&pos, &id) in positions.iter().zip(&sids) {
                if !s.rows.contains_key(&id) {
                    let seen = s.probation.entry(id).or_insert(0);
                    *seen += 1;
                    if *seen < self.entry_threshold {
                        continue;
                    }
                    s.probation.remove(&id);
                    s.graves.remove(&id);
                    let values = s.alloc_zeroed(self.row_store, width);
                    s.rows.insert(
                        id,
                        Row {
                            values,
                            last_access_ms: now_ms,
                            updates: 0,
                            epoch,
                            access_epoch: 0,
                        },
                    );
                }
                let row = s.rows.get_mut(&id).unwrap();
                row.updates += 1;
                row.last_access_ms = now_ms;
                row.epoch = epoch;
                self.optimizer
                    .apply(&mut row.values, &grads[pos * dim..(pos + 1) * dim], dim, row.updates);
                touched.push(id);
            }
            if touched.len() > before {
                s.max_epoch = s.max_epoch.max(epoch);
            }
        }
        touched
    }

    /// Batched-kernel update path: per stripe, run the entry filter, then
    /// — when that stripe's surviving group has at least `min_kernel_rows`
    /// ids — gather `(z, n)`, call `update(g, z, n, w)` (e.g. the AOT
    /// Pallas FTRL kernel), and scatter `(z, n, w)` back; smaller groups
    /// take the scalar optimizer instead, because the kernel pads every
    /// invocation to a full block and the crossover is **per invocation**,
    /// not per push. Each group runs entirely under its stripe's write
    /// lock, so per-id read-modify-write stays atomic while other stripes
    /// keep serving. Requires the 3-slot `(z, n, w)` layout.
    ///
    /// Materialized ids are appended to `touched` as each stripe commits;
    /// on a kernel error the already-committed stripes remain applied (and
    /// are reported through `touched` so callers can still sync them) —
    /// pushes are not cross-stripe transactions, exactly as a retried
    /// push after a lost ack was never idempotent. Returns the number of
    /// rows that went through the kernel (the rest went scalar).
    pub fn apply_batch_with<F>(
        &self,
        ids: &[u64],
        grads: &[f32],
        now_ms: u64,
        min_kernel_rows: usize,
        touched: &mut Vec<u64>,
        update: F,
    ) -> Result<u64>
    where
        F: Fn(&[f32], &mut [f32], &mut [f32], &mut [f32]) -> Result<()>,
    {
        let dim = self.dim;
        let width = self.row_width();
        debug_assert_eq!(grads.len(), ids.len() * dim);
        debug_assert_eq!(width, 3 * dim, "apply_batch_with needs a (z, n, w) slot layout");
        let mut kernel_rows = 0u64;
        for (stripe, (positions, sids)) in self.group_by_stripe(ids).into_iter().enumerate() {
            if sids.is_empty() {
                continue;
            }
            let mut s = self.stripes[stripe].write().unwrap();
            let epoch = self.write_epoch.load(Ordering::Relaxed);
            let mut ready: Vec<(usize, u64)> = Vec::with_capacity(sids.len());
            for (&pos, &id) in positions.iter().zip(&sids) {
                if !s.rows.contains_key(&id) {
                    let seen = s.probation.entry(id).or_insert(0);
                    *seen += 1;
                    if *seen < self.entry_threshold {
                        continue;
                    }
                    s.probation.remove(&id);
                    s.graves.remove(&id);
                    let values = s.alloc_zeroed(self.row_store, width);
                    s.rows.insert(
                        id,
                        Row {
                            values,
                            last_access_ms: now_ms,
                            updates: 0,
                            epoch,
                            access_epoch: 0,
                        },
                    );
                }
                ready.push((pos, id));
            }
            let k = ready.len();
            if k == 0 {
                continue;
            }
            s.max_epoch = s.max_epoch.max(epoch);
            if k < min_kernel_rows.max(1) {
                // Below the per-invocation crossover: scalar path.
                for (pos, id) in &ready {
                    let row = s.rows.get_mut(id).unwrap();
                    row.updates += 1;
                    row.last_access_ms = now_ms;
                    row.epoch = epoch;
                    self.optimizer.apply(
                        &mut row.values,
                        &grads[pos * dim..(pos + 1) * dim],
                        dim,
                        row.updates,
                    );
                    touched.push(*id);
                }
                continue;
            }
            let mut g = vec![0.0f32; k * dim];
            let mut z = vec![0.0f32; k * dim];
            let mut n = vec![0.0f32; k * dim];
            let mut w = vec![0.0f32; k * dim];
            for (i, (pos, id)) in ready.iter().enumerate() {
                g[i * dim..(i + 1) * dim].copy_from_slice(&grads[pos * dim..(pos + 1) * dim]);
                let row = &s.rows[id];
                z[i * dim..(i + 1) * dim].copy_from_slice(&row.values[..dim]);
                n[i * dim..(i + 1) * dim].copy_from_slice(&row.values[dim..2 * dim]);
            }
            update(&g, &mut z, &mut n, &mut w)?;
            for (i, (_, id)) in ready.iter().enumerate() {
                let row = s.rows.get_mut(id).unwrap();
                row.values[..dim].copy_from_slice(&z[i * dim..(i + 1) * dim]);
                row.values[dim..2 * dim].copy_from_slice(&n[i * dim..(i + 1) * dim]);
                row.values[2 * dim..].copy_from_slice(&w[i * dim..(i + 1) * dim]);
                row.updates += 1;
                row.last_access_ms = now_ms;
                row.epoch = epoch;
                touched.push(*id);
            }
            kernel_rows += k as u64;
        }
        Ok(kernel_rows)
    }

    /// Multi-batch coalesced row-op apply: `groups[s]` holds the full-row
    /// upserts (`Some(values)`) and deletes (`None`) whose ids hash to
    /// stripe `s`, accumulated across a whole run of sync batches **in
    /// arrival order** (so a later batch's op for an id wins, exactly as
    /// per-row application would). Each non-empty stripe takes its write
    /// lock once for the entire run — queue replay and scatter-style
    /// consumers pay one acquisition per busy stripe instead of one per
    /// row per batch. Width mismatches skip the op and the first such
    /// error is returned after everything else has applied (matching
    /// [`Self::upsert_row`]'s per-op validation). Returns rows touched.
    pub fn apply_grouped(&self, groups: &[RowOps<'_>], now_ms: u64) -> Result<u64> {
        debug_assert_eq!(groups.len(), self.stripes.len());
        let width = self.row_width();
        let mut touched = 0u64;
        let mut first_err: Option<Error> = None;
        for (stripe, ops) in groups.iter().enumerate() {
            if ops.is_empty() {
                continue;
            }
            let mut s = self.stripes[stripe].write().unwrap();
            let epoch = self.write_epoch.load(Ordering::Relaxed);
            s.max_epoch = s.max_epoch.max(epoch);
            for &(id, op) in ops {
                debug_assert_eq!(self.stripe_of(id), stripe, "op grouped to wrong stripe");
                match op {
                    Some(values) => {
                        if values.len() != width {
                            if first_err.is_none() {
                                first_err = Some(Error::Codec(format!(
                                    "row width {} != {width} for table {}",
                                    values.len(),
                                    self.name
                                )));
                            }
                            continue;
                        }
                        s.graves.remove(&id);
                        match s.rows.get_mut(&id) {
                            Some(row) => {
                                row.values.copy_from_slice(values);
                                row.last_access_ms = now_ms;
                                row.epoch = epoch;
                            }
                            None => {
                                let values = s.alloc_values(self.row_store, values);
                                s.rows.insert(
                                    id,
                                    Row {
                                        values,
                                        last_access_ms: now_ms,
                                        updates: 0,
                                        epoch,
                                        access_epoch: 0,
                                    },
                                );
                            }
                        }
                        touched += 1;
                    }
                    None => {
                        s.probation.remove(&id);
                        if s.rows.remove(&id).is_some() {
                            if self.track_graves.load(Ordering::Relaxed) {
                                s.graves.insert(id, epoch);
                            }
                            touched += 1;
                        }
                    }
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(touched),
        }
    }

    /// Overwrite a full row (scatter / checkpoint-load / replay path).
    pub fn upsert_row(&self, id: u64, values: &[f32], now_ms: u64) -> Result<()> {
        if values.len() != self.row_width() {
            return Err(Error::Codec(format!(
                "row width {} != {} for table {}",
                values.len(),
                self.row_width(),
                self.name
            )));
        }
        let mut s = self.stripes[self.stripe_of(id)].write().unwrap();
        let epoch = self.write_epoch.load(Ordering::Relaxed);
        s.max_epoch = s.max_epoch.max(epoch);
        s.graves.remove(&id);
        match s.rows.get_mut(&id) {
            Some(row) => {
                row.values.copy_from_slice(values);
                row.last_access_ms = now_ms;
                row.epoch = epoch;
            }
            None => {
                let values = s.alloc_values(self.row_store, values);
                s.rows.insert(
                    id,
                    Row {
                        values,
                        last_access_ms: now_ms,
                        updates: 0,
                        epoch,
                        access_epoch: 0,
                    },
                );
            }
        }
        Ok(())
    }

    /// Overwrite or insert a row with explicit metadata — the incremental
    /// chunk restore path ([`Self::decode_delta_rows`], WAL replay).
    /// Bypasses the entry filter and stamps `epoch` verbatim: chain
    /// restores pass 0 (clean), WAL replay passes the current write epoch
    /// so replayed rows are captured by the next delta.
    pub fn restore_row(
        &self,
        id: u64,
        values: &[f32],
        last_access_ms: u64,
        updates: u32,
        epoch: u64,
    ) -> Result<()> {
        if values.len() != self.row_width() {
            return Err(Error::Checkpoint(format!(
                "row width {} != {} for table {}",
                values.len(),
                self.row_width(),
                self.name
            )));
        }
        let mut s = self.stripes[self.stripe_of(id)].write().unwrap();
        s.max_epoch = s.max_epoch.max(epoch);
        s.probation.remove(&id);
        s.graves.remove(&id);
        match s.rows.get_mut(&id) {
            // Overwrite in place: replay/restore of an existing row must
            // not strand a fresh arena allocation per record.
            Some(row) => {
                row.values.copy_from_slice(values);
                row.last_access_ms = last_access_ms;
                row.updates = updates;
                row.epoch = epoch;
                row.access_epoch = 0;
            }
            None => {
                let values = s.alloc_values(self.row_store, values);
                s.rows.insert(
                    id,
                    Row { values, last_access_ms, updates, epoch, access_epoch: 0 },
                );
            }
        }
        Ok(())
    }

    /// Remove a row; true if it existed. Deletions leave a tombstone so
    /// delta chunks propagate them (pruned by [`Self::prune_graves`]).
    pub fn delete(&self, id: u64) -> bool {
        let mut s = self.stripes[self.stripe_of(id)].write().unwrap();
        let epoch = self.write_epoch.load(Ordering::Relaxed);
        s.probation.remove(&id);
        if s.rows.remove(&id).is_some() {
            if self.track_graves.load(Ordering::Relaxed) {
                s.graves.insert(id, epoch);
                s.max_epoch = s.max_epoch.max(epoch);
            }
            true
        } else {
            false
        }
    }

    /// Feature expire: evict rows untouched for `ttl_ms`, one stripe at a
    /// time (each stripe's clock is its rows' `last_access_ms`). Returns
    /// evicted ids (propagated to slaves as sync deletes). Probation
    /// entries age out wholesale per stripe, matching [`SparseTable`].
    pub fn expire(&self, now_ms: u64, ttl_ms: u64) -> Vec<u64> {
        self.expire_pooled(now_ms, ttl_ms, None)
    }

    /// [`Self::expire`] with the per-stripe scan+evict fanned out over
    /// `pool` (one task per stripe, each under its own stripe write lock).
    /// Evicted ids come back merged in stripe order regardless of pool
    /// size, so downstream sync-delete recording stays deterministic.
    pub fn expire_pooled(&self, now_ms: u64, ttl_ms: u64, pool: Option<&ThreadPool>) -> Vec<u64> {
        let write_epoch = &self.write_epoch;
        let track_graves = &self.track_graves;
        let row_store = self.row_store;
        let width = self.row_width();
        let expire_stripe = |stripe: &RwLock<Stripe>| -> Vec<u64> {
            let mut s = stripe.write().unwrap();
            let epoch = write_epoch.load(Ordering::Relaxed);
            let track = track_graves.load(Ordering::Relaxed);
            let stripe_dead: Vec<u64> = s
                .rows
                .iter()
                .filter(|(_, r)| now_ms.saturating_sub(r.last_access_ms) > ttl_ms)
                .map(|(id, _)| *id)
                .collect();
            for id in &stripe_dead {
                s.rows.remove(id);
                if track {
                    s.graves.insert(*id, epoch);
                }
            }
            if track && !stripe_dead.is_empty() {
                s.max_epoch = s.max_epoch.max(epoch);
            }
            s.probation.clear();
            // Arena compaction rides the sweep: once at least a quarter
            // of the stripe's arena is dead (evicted / overwritten rows),
            // rebuild it from the live rows so pull-path gathers keep
            // walking dense memory. Cost is O(live floats), the same
            // order as the scan that just ran.
            if row_store == RowStore::Arena {
                let live = s.rows.len() * width;
                let dead = s.arena.allocated.saturating_sub(live);
                if dead > 0 && (s.rows.is_empty() || dead * 4 >= s.arena.allocated) {
                    s.compact_arena();
                }
            }
            stripe_dead
        };
        let mut per_stripe: Vec<Vec<u64>> = (0..self.stripes.len()).map(|_| Vec::new()).collect();
        match pool {
            Some(pool) if self.stripes.len() > 1 => {
                let expire_stripe = &expire_stripe;
                let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = per_stripe
                    .iter_mut()
                    .zip(&self.stripes)
                    .map(|(slot, stripe)| {
                        Box::new(move || {
                            *slot = expire_stripe(stripe);
                        }) as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                pool.run_borrowed(tasks);
            }
            _ => {
                for (slot, stripe) in per_stripe.iter_mut().zip(&self.stripes) {
                    *slot = expire_stripe(stripe);
                }
            }
        }
        per_stripe.into_iter().flatten().collect()
    }

    /// All materialized ids (stripe order; no access-time touch).
    pub fn ids(&self) -> Vec<u64> {
        let mut out = Vec::new();
        for stripe in &self.stripes {
            out.extend(stripe.read().unwrap().rows.keys().copied());
        }
        out
    }

    /// Snapshot current full rows for `ids` without bumping access times
    /// (gather's value snapshot). One stripe read-lock per touched stripe,
    /// so a snapshot never blocks behind writes on other stripes. Results
    /// come back grouped by stripe.
    pub fn read_rows(&self, ids: &[u64]) -> RowSnapshot {
        let mut out = Vec::with_capacity(ids.len());
        for (stripe, (_, sids)) in self.group_by_stripe(ids).into_iter().enumerate() {
            if sids.is_empty() {
                continue;
            }
            let s = self.stripes[stripe].read().unwrap();
            for id in sids {
                out.push((id, s.rows.get(&id).map(|r| r.values.to_vec())));
            }
        }
        out
    }

    /// Snapshot full rows for ids already grouped by stripe — the striped
    /// collector hands gather exactly this shape, so no flush-time re-hash
    /// is needed. `groups[s]` must hold only ids whose [`Self::stripe_of`]
    /// is `s` and `groups.len()` must equal the stripe count (callers
    /// built from the same-striped collector satisfy both by
    /// construction). Each stripe's snapshot runs under that stripe's
    /// *read* lock only; with `pool`, non-empty stripes snapshot
    /// concurrently (read-lock held only inside the task). Results come
    /// back per stripe, in stripe order, independent of pool size.
    pub fn read_rows_grouped(
        &self,
        groups: &[Vec<u64>],
        pool: Option<&ThreadPool>,
    ) -> Vec<RowSnapshot> {
        debug_assert_eq!(groups.len(), self.stripes.len());
        debug_assert!(groups
            .iter()
            .enumerate()
            .all(|(s, g)| g.iter().all(|&id| self.stripe_of(id) == s)));
        let snapshot_stripe = |stripe: &RwLock<Stripe>, ids: &[u64]| -> RowSnapshot {
            let s = stripe.read().unwrap();
            ids.iter()
                .map(|id| (*id, s.rows.get(id).map(|r| r.values.to_vec())))
                .collect()
        };
        let mut out: Vec<RowSnapshot> = (0..groups.len()).map(|_| Vec::new()).collect();
        let busy = groups.iter().filter(|g| !g.is_empty()).count();
        match pool {
            // With one busy stripe there is nothing to overlap; skip the
            // pool round-trip.
            Some(pool) if busy > 1 => {
                let snapshot_stripe = &snapshot_stripe;
                let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = out
                    .iter_mut()
                    .zip(&self.stripes)
                    .zip(groups)
                    .filter(|((_, _), g)| !g.is_empty())
                    .map(|((slot, stripe), g)| {
                        Box::new(move || {
                            *slot = snapshot_stripe(stripe, g);
                        }) as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                pool.run_borrowed(tasks);
            }
            _ => {
                for ((slot, stripe), g) in out.iter_mut().zip(&self.stripes).zip(groups) {
                    if !g.is_empty() {
                        *slot = snapshot_stripe(stripe, g);
                    }
                }
            }
        }
        out
    }

    /// Collect the dirty set since epoch `since`: full rows whose last
    /// mutation epoch is `> since`, plus tombstones for rows deleted
    /// after it. Scans one stripe at a time under that stripe's *read*
    /// lock only — a delta collection never blocks writers on other
    /// stripes (the "training never globally stalls" property of
    /// incremental checkpoints). An id appears in at most one of the two
    /// lists (re-inserting a deleted row clears its grave). Results are
    /// sorted by id, so downstream chunk bytes are deterministic for any
    /// stripe count.
    pub fn collect_delta(&self, since: u64) -> (Vec<DeltaRow>, Vec<u64>) {
        let mut upserts = Vec::new();
        let mut deletes = Vec::new();
        for stripe in &self.stripes {
            let s = stripe.read().unwrap();
            if s.max_epoch <= since {
                continue;
            }
            for (id, row) in &s.rows {
                // Value *or* access-time mutations count: recovery must
                // reproduce `last_access_ms` freshness (expire fidelity).
                if row.epoch.max(row.access_epoch) > since {
                    upserts.push(DeltaRow {
                        id: *id,
                        last_access_ms: row.last_access_ms,
                        updates: row.updates,
                        values: row.values.to_vec(),
                    });
                }
            }
            for (id, &epoch) in &s.graves {
                if epoch > since {
                    deletes.push(*id);
                }
            }
        }
        upserts.sort_unstable_by_key(|r| r.id);
        deletes.sort_unstable();
        (upserts, deletes)
    }

    /// (dirty rows, tombstones) since `since` — checkpoint sizing and the
    /// recovery bench's dirty-set scaling measurements.
    pub fn dirty_counts(&self, since: u64) -> (usize, usize) {
        let mut rows = 0;
        let mut graves = 0;
        for stripe in &self.stripes {
            let s = stripe.read().unwrap();
            if s.max_epoch <= since {
                continue;
            }
            rows += s.rows.values().filter(|r| r.epoch.max(r.access_epoch) > since).count();
            graves += s.graves.values().filter(|&&e| e > since).count();
        }
        (rows, graves)
    }

    /// Split dirty census since `since`: (value-dirty rows, tombstones,
    /// access-only rows). A row is access-only when a pull refreshed its
    /// `last_access_ms` after the cut but no value mutation did — the
    /// case the WAL can journal as a metadata-only record instead of
    /// shipping full rows.
    pub fn dirty_counts_split(&self, since: u64) -> (usize, usize, usize) {
        let mut rows = 0;
        let mut graves = 0;
        let mut access = 0;
        for stripe in &self.stripes {
            let s = stripe.read().unwrap();
            if s.max_epoch <= since {
                continue;
            }
            for r in s.rows.values() {
                if r.epoch > since {
                    rows += 1;
                } else if r.access_epoch > since {
                    access += 1;
                }
            }
            graves += s.graves.values().filter(|&&e| e > since).count();
        }
        (rows, graves, access)
    }

    /// Collect `(id, last_access_ms)` for access-only rows since `since`
    /// — the payload of a metadata-only WAL record. Sorted by id
    /// (deterministic bytes for any stripe count); takes stripe read
    /// locks only.
    pub fn collect_access_stamps(&self, since: u64) -> Vec<(u64, u64)> {
        let mut stamps = Vec::new();
        for stripe in &self.stripes {
            let s = stripe.read().unwrap();
            if s.max_epoch <= since {
                continue;
            }
            for (id, r) in &s.rows {
                if r.epoch <= since && r.access_epoch > since {
                    stamps.push((*id, r.last_access_ms));
                }
            }
        }
        stamps.sort_unstable_by_key(|&(id, _)| id);
        stamps
    }

    /// Apply access stamps from a metadata-only WAL record: move each
    /// surviving row's `last_access_ms` forward (never backward —
    /// replays are idempotent and may interleave with fresher traffic)
    /// and re-stamp its `access_epoch` with the current write epoch so
    /// the next checkpoint delta captures the freshness. Ids with no row
    /// are skipped: the stamp is advisory metadata, not a value. Returns
    /// rows refreshed.
    pub fn apply_access_stamps(&self, stamps: &[(u64, u64)]) -> usize {
        let mut refreshed = 0usize;
        let ids: Vec<u64> = stamps.iter().map(|&(id, _)| id).collect();
        for (stripe, (positions, sids)) in self.group_by_stripe(&ids).into_iter().enumerate() {
            if sids.is_empty() {
                continue;
            }
            let mut s = self.stripes[stripe].write().unwrap();
            let epoch = self.write_epoch.load(Ordering::Relaxed);
            let mut touched = false;
            for (&pos, id) in positions.iter().zip(&sids) {
                let last_access_ms = stamps[pos].1;
                if let Some(row) = s.rows.get_mut(id) {
                    if row.last_access_ms < last_access_ms {
                        row.last_access_ms = last_access_ms;
                        if row.access_epoch < epoch {
                            row.access_epoch = epoch;
                        }
                        touched = true;
                        refreshed += 1;
                    }
                }
            }
            if touched {
                s.max_epoch = s.max_epoch.max(epoch);
            }
        }
        refreshed
    }

    /// Drop tombstones stamped `<= through`. Called after the checkpoint
    /// that sealed them: every future delta's `since` is at least
    /// `through`, so those graves can never be collected again.
    pub fn prune_graves(&self, through: u64) {
        for stripe in &self.stripes {
            let mut s = stripe.write().unwrap();
            s.graves.retain(|_, e| *e > through);
        }
    }

    /// Slot-filtered variant of [`Self::collect_delta`] — the live-
    /// migration copy path. `since = None` collects **every** row whose
    /// id hashes into `slots` regardless of epoch (the base pass; clean
    /// restored rows carry epoch 0 and must move too); `Some(cut)`
    /// collects only rows/graves **value**-stamped after `cut` (catch-up
    /// rounds; access-time-only refreshes are deliberately excluded so
    /// catch-up converges under read-heavy load — each copied row still
    /// carries the access time it had when copied). Results are sorted
    /// by id (deterministic chunk bytes for any stripe count).
    pub fn collect_slot_delta(
        &self,
        since: Option<u64>,
        slots: &crate::reshard::SlotSet,
    ) -> (Vec<DeltaRow>, Vec<u64>) {
        let universe = slots.universe();
        let mut upserts = Vec::new();
        let mut deletes = Vec::new();
        for stripe in &self.stripes {
            let s = stripe.read().unwrap();
            if let Some(cut) = since {
                if s.max_epoch <= cut {
                    continue;
                }
            }
            for (id, row) in &s.rows {
                if let Some(cut) = since {
                    if row.epoch <= cut {
                        continue;
                    }
                }
                if !slots.contains(crate::reshard::slot_of(*id, universe)) {
                    continue;
                }
                upserts.push(DeltaRow {
                    id: *id,
                    last_access_ms: row.last_access_ms,
                    updates: row.updates,
                    values: row.values.to_vec(),
                });
            }
            if let Some(cut) = since {
                for (id, &epoch) in &s.graves {
                    if epoch > cut && slots.contains(crate::reshard::slot_of(*id, universe)) {
                        deletes.push(*id);
                    }
                }
            }
        }
        upserts.sort_unstable_by_key(|r| r.id);
        deletes.sort_unstable();
        (upserts, deletes)
    }

    /// Serialize one table delta section — the single wire shape shared
    /// by checkpoint deltas and migration slot chunks (and decoded by
    /// [`Self::decode_delta_rows`]): schema header, full rows with
    /// metadata, then tombstone ids.
    fn write_delta_section(&self, upserts: &[DeltaRow], deletes: &[u64], w: &mut Writer) {
        w.put_str(&self.name);
        w.put_u32(self.dim as u32);
        w.put_u32(self.row_width() as u32);
        w.put_varint(upserts.len() as u64);
        for row in upserts {
            w.put_varint(row.id);
            w.put_varint(row.last_access_ms);
            w.put_u32(row.updates);
            w.put_f32_slice(&row.values);
        }
        w.put_varint(deletes.len() as u64);
        for id in deletes {
            w.put_varint(*id);
        }
    }

    /// Serialize a slot-filtered delta section in the exact wire shape of
    /// [`Self::encode_delta_rows`], so [`Self::decode_delta_rows`]
    /// applies it on the migration recipient. Returns (upserts, deletes)
    /// written.
    pub fn encode_slot_delta_rows(
        &self,
        since: Option<u64>,
        slots: &crate::reshard::SlotSet,
        w: &mut Writer,
    ) -> (usize, usize) {
        let (upserts, deletes) = self.collect_slot_delta(since, slots);
        self.write_delta_section(&upserts, &deletes, w);
        (upserts.len(), deletes.len())
    }

    /// Silently remove every row, probation entry and tombstone whose id
    /// hashes into `slots`: **no** graves are left and **no** epochs are
    /// stamped — the migration hand-off, where the recipient's checkpoint
    /// lineage owns the rows from now on and a donor-side tombstone would
    /// wrongly propagate deletes for live rows. Returns rows removed.
    pub fn purge_slots(&self, slots: &crate::reshard::SlotSet) -> usize {
        let universe = slots.universe();
        let mut removed = 0;
        for stripe in &self.stripes {
            let mut s = stripe.write().unwrap();
            s.rows.retain(|id, _| {
                let keep = !slots.contains(crate::reshard::slot_of(*id, universe));
                if !keep {
                    removed += 1;
                }
                keep
            });
            s.probation.retain(|id, _| !slots.contains(crate::reshard::slot_of(*id, universe)));
            s.graves.retain(|id, _| !slots.contains(crate::reshard::slot_of(*id, universe)));
        }
        removed
    }

    /// Serialize the dirty set since `since` as one table section of a
    /// delta chunk: schema header, full dirty rows (with metadata, so a
    /// restore is byte-identical to the uninterrupted state), then
    /// tombstone ids. Returns (upserts, deletes) written.
    pub fn encode_delta_rows(&self, since: u64, w: &mut Writer) -> (usize, usize) {
        let (upserts, deletes) = self.collect_delta(since);
        self.write_delta_section(&upserts, &deletes, w);
        (upserts.len(), deletes.len())
    }

    /// Apply one table section written by [`Self::encode_delta_rows`].
    /// `stamp` is the epoch applied rows carry afterwards: chain restores
    /// pass 0 (clean — the restored state is exactly what the chunk's
    /// checkpoint already covers), WAL replay passes the current write
    /// epoch so replayed rows are dirty again and the next delta captures
    /// them. Returns (rows upserted, rows deleted).
    pub fn decode_delta_rows(&self, r: &mut Reader, stamp: u64) -> Result<(usize, usize)> {
        let name = r.get_str()?;
        if name != self.name {
            return Err(Error::Checkpoint(format!("delta table {name} != {}", self.name)));
        }
        let dim = r.get_u32()? as usize;
        let width = r.get_u32()? as usize;
        if dim != self.dim || width != self.row_width() {
            return Err(Error::Checkpoint(format!(
                "table {} delta schema mismatch: dim {dim}/{} width {width}/{}",
                self.name,
                self.dim,
                self.row_width()
            )));
        }
        let n_upserts = r.get_varint()? as usize;
        for _ in 0..n_upserts {
            let id = r.get_varint()?;
            let last_access_ms = r.get_varint()?;
            let updates = r.get_u32()?;
            let values = r.get_f32_slice()?;
            self.restore_row(id, &values, last_access_ms, updates, stamp)?;
        }
        let n_deletes = r.get_varint()? as usize;
        let mut deleted = 0;
        for _ in 0..n_deletes {
            let id = r.get_varint()?;
            let mut s = self.stripes[self.stripe_of(id)].write().unwrap();
            s.probation.remove(&id);
            if s.rows.remove(&id).is_some() {
                deleted += 1;
            }
            // Tombstones inherit `stamp` exactly like upserts: a chain
            // restore (stamp 0) must not plant far-future graves that
            // every later delta re-collects until the epoch counter
            // catches up; a WAL replay (stamp = live epoch) must leave
            // one so the next sealed chunk propagates the delete.
            if stamp > 0 && self.track_graves.load(Ordering::Relaxed) {
                s.graves.insert(id, stamp);
                s.max_epoch = s.max_epoch.max(stamp);
            }
        }
        Ok((n_upserts, deleted))
    }

    /// Serialize every row (checkpoint shard payload). Byte-compatible
    /// with [`SparseTable::encode_rows`], but **deterministic**: rows are
    /// emitted in ascending id order regardless of stripe count, so the
    /// same logical state snapshots to the same bytes on any topology.
    /// Stripe guards are acquired in ascending stripe order (the global
    /// lock-ordering rule for multi-stripe operations).
    pub fn encode_rows(&self, w: &mut Writer) {
        w.put_str(&self.name);
        w.put_u32(self.dim as u32);
        w.put_u32(self.row_width() as u32);
        let guards: Vec<_> = self.stripes.iter().map(|s| s.read().unwrap()).collect();
        let mut refs: Vec<(&u64, &Row)> = guards.iter().flat_map(|g| g.rows.iter()).collect();
        refs.sort_unstable_by_key(|(id, _)| **id);
        w.put_varint(refs.len() as u64);
        for (id, row) in refs {
            w.put_varint(*id);
            w.put_varint(row.last_access_ms);
            w.put_u32(row.updates);
            w.put_f32_slice(&row.values);
        }
    }

    /// Restore rows from a checkpoint (replaces current content; accepts
    /// snapshots written by any stripe count or by [`SparseTable`]).
    pub fn decode_rows(&self, r: &mut Reader) -> Result<()> {
        let name = r.get_str()?;
        if name != self.name {
            return Err(Error::Checkpoint(format!("checkpoint table {name} != {}", self.name)));
        }
        let dim = r.get_u32()? as usize;
        let width = r.get_u32()? as usize;
        if dim != self.dim || width != self.row_width() {
            return Err(Error::Checkpoint(format!(
                "table {} schema mismatch: dim {dim}/{} width {width}/{}",
                self.name,
                self.dim,
                self.row_width()
            )));
        }
        let count = r.get_varint()? as usize;
        let mut guards: Vec<_> = self.stripes.iter().map(|s| s.write().unwrap()).collect();
        for g in guards.iter_mut() {
            g.rows.clear();
            g.probation.clear();
            // A full restore replaces everything: restored rows are clean
            // (epoch 0) and pre-restore tombstones are meaningless. The
            // arena resets with the rows (safe: the row map was cleared
            // first, so nothing points into the dropped chunks).
            g.graves.clear();
            g.max_epoch = 0;
            g.arena.reset();
        }
        for _ in 0..count {
            let id = r.get_varint()?;
            let last_access_ms = r.get_varint()?;
            let updates = r.get_u32()?;
            let values = r.get_f32_slice()?;
            if values.len() != width {
                return Err(Error::Checkpoint(format!(
                    "row {id} width {} != {width}",
                    values.len()
                )));
            }
            let g = &mut guards[self.stripe_of(id)];
            let values = g.adopt_values(self.row_store, values);
            g.rows.insert(
                id,
                Row {
                    values,
                    last_access_ms,
                    updates,
                    epoch: 0,
                    access_epoch: 0,
                },
            );
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Dense tables
// ---------------------------------------------------------------------------

/// Dense optimizer for tower weights (SGD or Adagrad with internal state).
#[derive(Debug, Clone)]
pub enum DenseOpt {
    Sgd { lr: f32 },
    Adagrad { lr: f32, eps: f32 },
}

/// A dense parameter tensor (MLP tower weights, bias) with optimizer state.
pub struct DenseTable {
    name: String,
    values: Vec<f32>,
    acc: Vec<f32>,
    opt: DenseOpt,
    /// Bumped on every update; slaves use it to detect staleness.
    pub version: u64,
}

impl DenseTable {
    /// New dense table with `init` values.
    pub fn new(name: impl Into<String>, init: Vec<f32>, opt: DenseOpt) -> DenseTable {
        let acc = vec![0.0; init.len()];
        DenseTable { name: name.into(), values: init, acc, opt, version: 0 }
    }

    /// Table name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Current values.
    pub fn values(&self) -> &[f32] {
        &self.values
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.values.len()
    }

    /// True when the table is empty.
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Apply a gradient of the same length.
    pub fn apply_grad(&mut self, grad: &[f32]) -> Result<()> {
        if grad.len() != self.values.len() {
            return Err(Error::Codec(format!(
                "dense grad len {} != {} for {}",
                grad.len(),
                self.values.len(),
                self.name
            )));
        }
        match self.opt {
            DenseOpt::Sgd { lr } => {
                for (w, g) in self.values.iter_mut().zip(grad) {
                    *w -= lr * g;
                }
            }
            DenseOpt::Adagrad { lr, eps } => {
                for ((w, a), g) in self.values.iter_mut().zip(&mut self.acc).zip(grad) {
                    *a += g * g;
                    *w -= lr * g / (a.sqrt() + eps);
                }
            }
        }
        self.version += 1;
        Ok(())
    }

    /// Overwrite values (scatter / checkpoint load).
    pub fn set_values(&mut self, values: &[f32]) -> Result<()> {
        if values.len() != self.values.len() {
            return Err(Error::Codec(format!(
                "dense set len {} != {} for {}",
                values.len(),
                self.values.len(),
                self.name
            )));
        }
        self.values.copy_from_slice(values);
        self.version += 1;
        Ok(())
    }
}

impl Encode for DenseTable {
    fn encode(&self, w: &mut Writer) {
        w.put_str(&self.name);
        w.put_u64(self.version);
        w.put_f32_slice(&self.values);
        w.put_f32_slice(&self.acc);
    }
}

impl DenseTable {
    /// Restore state saved by [`Encode::encode`] into this table.
    pub fn decode_into(&mut self, r: &mut Reader) -> Result<()> {
        let name = r.get_str()?;
        if name != self.name {
            return Err(Error::Checkpoint(format!("dense table {name} != {}", self.name)));
        }
        self.version = r.get_u64()?;
        let values = r.get_f32_slice()?;
        let acc = r.get_f32_slice()?;
        if values.len() != self.values.len() {
            return Err(Error::Checkpoint(format!(
                "dense {} len {} != {}",
                self.name,
                values.len(),
                self.values.len()
            )));
        }
        self.values = values;
        self.acc = acc;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{Ftrl, FtrlHyper, Sgd};

    fn table(threshold: u32) -> SparseTable {
        SparseTable::new("w", 2, Arc::new(Ftrl::new(FtrlHyper::default())), threshold)
    }

    #[test]
    fn pull_missing_ids_is_zero() {
        let mut t = table(1);
        let mut out = vec![9.0; 6];
        t.pull_slot(&[1, 2, 3], "w", 0, &mut out).unwrap();
        assert_eq!(out, vec![0.0; 6]);
        assert!(t.pull_slot(&[1], "nope", 0, &mut [0.0; 2]).is_err());
    }

    #[test]
    fn apply_then_pull_round_trips() {
        let mut t = table(1);
        let touched = t.apply_grads(&[7, 8], &[1.0, 1.0, -1.0, -1.0], 100);
        assert_eq!(touched, vec![7, 8]);
        assert_eq!(t.len(), 2);
        let mut z = vec![0.0; 2];
        t.pull_slot(&[7], "z", 100, &mut z).unwrap();
        assert_eq!(z, vec![1.0, 1.0]); // z = g on first update from zero
        let mut n = vec![0.0; 2];
        t.pull_slot(&[8], "n", 100, &mut n).unwrap();
        assert_eq!(n, vec![1.0, 1.0]); // n = g^2
    }

    #[test]
    fn entry_filter_defers_materialization() {
        let mut t = table(3);
        assert!(t.apply_grads(&[5], &[1.0, 1.0], 0).is_empty());
        assert!(t.apply_grads(&[5], &[1.0, 1.0], 0).is_empty());
        assert_eq!(t.len(), 0);
        // Third observation materializes and applies.
        assert_eq!(t.apply_grads(&[5], &[1.0, 1.0], 0), vec![5]);
        assert_eq!(t.len(), 1);
        assert_eq!(t.get_row(5).unwrap().updates, 1);
    }

    #[test]
    fn expire_evicts_stale_rows() {
        let mut t = table(1);
        t.apply_grads(&[1], &[1.0, 1.0], 1_000);
        t.apply_grads(&[2], &[1.0, 1.0], 5_000);
        let dead = t.expire(10_000, 6_000);
        assert_eq!(dead, vec![1]);
        assert_eq!(t.len(), 1);
        assert!(t.get_row(2).is_some());
        // Access refreshes the clock.
        let mut out = vec![0.0; 2];
        t.pull_slot(&[2], "w", 20_000, &mut out).unwrap();
        assert!(t.expire(24_000, 6_000).is_empty());
    }

    #[test]
    fn delete_removes_row_and_probation() {
        let mut t = table(2);
        t.apply_grads(&[9], &[1.0, 1.0], 0); // probation only
        assert!(!t.delete(9)); // not materialized
        t.apply_grads(&[9], &[1.0, 1.0], 0);
        t.apply_grads(&[9], &[1.0, 1.0], 0);
        assert_eq!(t.len(), 1);
        assert!(t.delete(9));
        assert_eq!(t.len(), 0);
    }

    #[test]
    fn upsert_validates_width() {
        let mut t = table(1);
        assert!(t.upsert_row(1, &[0.0; 6], 0).is_ok()); // 3 slots * dim 2
        assert!(t.upsert_row(1, &[0.0; 4], 0).is_err());
        t.upsert_row(1, &[1., 2., 3., 4., 5., 6.], 0).unwrap();
        assert_eq!(&*t.get_row(1).unwrap().values, &[1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn checkpoint_round_trip() {
        let mut t = table(1);
        for id in 0..100u64 {
            t.apply_grads(&[id], &[id as f32 * 0.1, -0.5], 50);
        }
        let mut w = Writer::new();
        t.encode_rows(&mut w);
        let bytes = w.into_bytes();

        let mut t2 = table(1);
        t2.decode_rows(&mut Reader::new(&bytes)).unwrap();
        assert_eq!(t2.len(), 100);
        for id in 0..100u64 {
            assert_eq!(t.get_row(id).unwrap(), t2.get_row(id).unwrap(), "row {id}");
        }
    }

    #[test]
    fn checkpoint_schema_mismatch_rejected() {
        let mut t = table(1);
        t.apply_grads(&[1], &[1.0, 1.0], 0);
        let mut w = Writer::new();
        t.encode_rows(&mut w);
        let bytes = w.into_bytes();
        // dim-4 table refuses a dim-2 checkpoint.
        let mut t4 = SparseTable::new("w", 4, Arc::new(Ftrl::new(FtrlHyper::default())), 1);
        assert!(t4.decode_rows(&mut Reader::new(&bytes)).is_err());
        // Different name refuses too.
        let mut tn = SparseTable::new("v", 2, Arc::new(Ftrl::new(FtrlHyper::default())), 1);
        assert!(tn.decode_rows(&mut Reader::new(&bytes)).is_err());
    }

    #[test]
    fn aggregate_grads_sums_duplicates() {
        let (ids, grads) = aggregate_grads(
            &[3, 5, 3, 5, 7],
            &[1., 1., 2., 2., 10., 10., 20., 20., 5., 5.],
            2,
        );
        assert_eq!(ids, vec![3, 5, 7]);
        assert_eq!(grads, vec![11., 11., 22., 22., 5., 5.]);
    }

    #[test]
    fn prop_aggregate_preserves_total_mass() {
        use crate::util::prop::{check, PairOf, U64Range, VecOf};
        check(
            "aggregate-mass",
            &VecOf(PairOf(U64Range(0, 9), U64Range(0, 100)), 64),
            200,
            |pairs| {
                let ids: Vec<u64> = pairs.iter().map(|(id, _)| *id).collect();
                let grads: Vec<f32> = pairs.iter().map(|(_, g)| *g as f32).collect();
                let (uids, ugrads) = aggregate_grads(&ids, &grads, 1);
                let total_in: f32 = grads.iter().sum();
                let total_out: f32 = ugrads.iter().sum();
                if (total_in - total_out).abs() > 1e-3 {
                    return Err(format!("mass {total_in} -> {total_out}"));
                }
                let mut sorted = uids.clone();
                sorted.sort();
                sorted.dedup();
                if sorted.len() != uids.len() {
                    return Err("duplicate ids in output".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn dense_sgd_and_adagrad() {
        let mut d = DenseTable::new("b", vec![1.0, 2.0], DenseOpt::Sgd { lr: 0.5 });
        d.apply_grad(&[1.0, -1.0]).unwrap();
        assert_eq!(d.values(), &[0.5, 2.5]);
        assert_eq!(d.version, 1);
        assert!(d.apply_grad(&[1.0]).is_err());

        let mut a = DenseTable::new("w1", vec![0.0; 2], DenseOpt::Adagrad { lr: 0.1, eps: 1e-8 });
        a.apply_grad(&[1.0, 1.0]).unwrap();
        let first = -a.values()[0];
        a.apply_grad(&[1.0, 1.0]).unwrap();
        let second = first - (-a.values()[0] - first) ; // step sizes shrink
        assert!(first > 0.0 && second > 0.0);
    }

    #[test]
    fn dense_checkpoint_round_trip() {
        let mut d = DenseTable::new("w1", vec![0.0; 8], DenseOpt::Adagrad { lr: 0.1, eps: 1e-8 });
        d.apply_grad(&[0.5; 8]).unwrap();
        d.apply_grad(&[-0.25; 8]).unwrap();
        let bytes = d.to_bytes();

        let mut d2 = DenseTable::new("w1", vec![0.0; 8], DenseOpt::Adagrad { lr: 0.1, eps: 1e-8 });
        d2.decode_into(&mut Reader::new(&bytes)).unwrap();
        assert_eq!(d2.values(), d.values());
        assert_eq!(d2.version, d.version);
        // Post-restore updates continue from restored adagrad state.
        d.apply_grad(&[0.1; 8]).unwrap();
        d2.apply_grad(&[0.1; 8]).unwrap();
        assert_eq!(d.values(), d2.values());
    }

    #[test]
    fn sgd_table_slot_layout() {
        let mut t = SparseTable::new("w", 4, Arc::new(Sgd { lr: 0.1 }), 1);
        t.apply_grads(&[1], &[1.0, 2.0, 3.0, 4.0], 0);
        let row = t.get_row(1).unwrap();
        assert_eq!(row.values.len(), 4); // single slot
        assert_eq!(&*row.values, &[-0.1, -0.2, -0.3, -0.4]);
    }

    // -- StripedSparseTable ---------------------------------------------------

    fn striped(threshold: u32, stripes: usize) -> StripedSparseTable {
        StripedSparseTable::new(
            "w",
            2,
            Arc::new(Ftrl::new(FtrlHyper::default())),
            threshold,
            stripes,
        )
    }

    #[test]
    fn striped_apply_then_pull_round_trips() {
        let t = striped(1, 8);
        let ids: Vec<u64> = (0..64).collect();
        let grads: Vec<f32> = ids.iter().flat_map(|_| [1.0, -1.0]).collect();
        let touched = t.apply_batch(&ids, &grads, 100);
        assert_eq!(touched.len(), 64); // every id materialized
        assert_eq!(t.len(), 64);
        // Ids spread over more than one stripe.
        let distinct: std::collections::HashSet<usize> =
            ids.iter().map(|&id| t.stripe_of(id)).collect();
        assert!(distinct.len() > 1, "64 ids landed on one stripe");
        let mut z = vec![0.0; ids.len() * 2];
        t.pull_slot(&ids, "z", 100, &mut z).unwrap();
        for pair in z.chunks(2) {
            assert_eq!(pair, &[1.0, -1.0]); // z = g on first update
        }
        // Missing ids pull zero; unknown slot errors.
        let mut out = vec![9.0; 2];
        t.pull_slot(&[1_000_000], "z", 0, &mut out).unwrap();
        assert_eq!(out, vec![0.0, 0.0]);
        assert!(t.pull_slot(&[1], "nope", 0, &mut [0.0; 2]).is_err());
    }

    #[test]
    fn striped_entry_filter_never_materializes_below_threshold() {
        let t = striped(3, 8);
        let ids: Vec<u64> = (0..40).collect();
        let grads = vec![0.5f32; ids.len() * 2];
        // Two observations: below threshold, no stripe may hold a row.
        assert!(t.apply_batch(&ids, &grads, 0).is_empty());
        assert!(t.apply_batch(&ids, &grads, 0).is_empty());
        assert_eq!(t.len(), 0);
        for (i, stripe) in t.stripes.iter().enumerate() {
            let s = stripe.read().unwrap();
            assert!(s.rows.is_empty(), "stripe {i} materialized early");
            assert!(!s.probation.is_empty() || s.rows.is_empty());
        }
        // Third observation materializes everything, each in its stripe.
        let touched = t.apply_batch(&ids, &grads, 0);
        assert_eq!(touched.len(), ids.len());
        assert_eq!(t.len(), ids.len());
        for &id in &ids {
            let s = t.stripes[t.stripe_of(id)].read().unwrap();
            assert!(s.rows.contains_key(&id), "id {id} not in its owning stripe");
            assert!(!s.probation.contains_key(&id), "id {id} still on probation");
        }
    }

    #[test]
    fn striped_expire_evicts_from_owning_stripe() {
        let t = striped(1, 4);
        let old_ids: Vec<u64> = (0..20).collect();
        let new_ids: Vec<u64> = (100..120).collect();
        t.apply_batch(&old_ids, &vec![1.0f32; 40], 1_000);
        t.apply_batch(&new_ids, &vec![1.0f32; 40], 9_000);
        let mut dead = t.expire(10_000, 5_000);
        dead.sort_unstable();
        assert_eq!(dead, old_ids);
        assert_eq!(t.len(), new_ids.len());
        for &id in &old_ids {
            assert!(t.get_row(id).is_none());
            assert!(!t.stripes[t.stripe_of(id)].read().unwrap().rows.contains_key(&id));
        }
        // Access refreshes the expire clock stripe-locally.
        let mut out = vec![0.0; 2];
        t.pull_slot(&[100], "w", 20_000, &mut out).unwrap();
        let dead = t.expire(24_000, 5_000);
        assert_eq!(dead.len(), new_ids.len() - 1);
        assert_eq!(t.len(), 1);
        assert!(t.get_row(100).is_some());
    }

    #[test]
    fn striped_checkpoint_deterministic_across_stripe_counts() {
        let mut snapshots = Vec::new();
        for stripes in [1usize, 2, 8, 32] {
            let t = striped(1, stripes);
            // Insert in different orders per stripe count to prove the
            // encoding canonicalizes.
            let mut ids: Vec<u64> = (0..200).map(|i| i * 7 + 3).collect();
            if stripes % 2 == 0 {
                ids.reverse();
            }
            for id in ids {
                t.apply_batch(&[id], &[id as f32 * 0.01, -0.5], 42);
            }
            let mut w = Writer::new();
            t.encode_rows(&mut w);
            snapshots.push(w.into_bytes());
        }
        for s in &snapshots[1..] {
            assert_eq!(s, &snapshots[0], "snapshot bytes differ across stripe counts");
        }
        // And the bytes decode into both table kinds.
        let t8 = striped(1, 8);
        t8.decode_rows(&mut Reader::new(&snapshots[0])).unwrap();
        assert_eq!(t8.len(), 200);
        let mut legacy = table(1);
        legacy.decode_rows(&mut Reader::new(&snapshots[0])).unwrap();
        assert_eq!(legacy.len(), 200);
        for (&id, row) in legacy.iter() {
            assert_eq!(t8.get_row(id).as_ref(), Some(row), "row {id}");
        }
    }

    #[test]
    fn striped_decode_rejects_schema_mismatch() {
        let t = striped(1, 4);
        t.apply_batch(&[1], &[1.0, 1.0], 0);
        let mut w = Writer::new();
        t.encode_rows(&mut w);
        let bytes = w.into_bytes();
        let wrong_dim = StripedSparseTable::new(
            "w",
            4,
            Arc::new(Ftrl::new(FtrlHyper::default())),
            1,
            4,
        );
        assert!(wrong_dim.decode_rows(&mut Reader::new(&bytes)).is_err());
        let wrong_name = StripedSparseTable::new(
            "v",
            2,
            Arc::new(Ftrl::new(FtrlHyper::default())),
            1,
            4,
        );
        assert!(wrong_name.decode_rows(&mut Reader::new(&bytes)).is_err());
    }

    #[test]
    fn striped_reads_do_not_block_behind_other_stripes() {
        // Direct lock-independence probe: hold a *write* guard on id A's
        // stripe, then batch-read and batch-write ids of a different
        // stripe on the same thread. With one table-wide lock this
        // deadlocks (test hangs); with striping it completes.
        let t = striped(1, 8);
        let ids: Vec<u64> = (0..256).collect();
        let grads = vec![0.1f32; ids.len() * 2];
        t.apply_batch(&ids, &grads, 0);
        let a = ids[0];
        let stripe_a = t.stripe_of(a);
        let others: Vec<u64> =
            ids.iter().copied().filter(|&id| t.stripe_of(id) != stripe_a).collect();
        assert!(!others.is_empty());
        let _guard = t.stripes[stripe_a].write().unwrap();
        // Gather snapshot of other stripes proceeds under the held guard.
        let rows = t.read_rows(&others);
        assert_eq!(rows.len(), others.len());
        assert!(rows.iter().all(|(_, r)| r.is_some()));
        // So does an optimizer apply on other stripes.
        let touched =
            t.apply_batch(&others, &vec![0.1f32; others.len() * 2], 1);
        assert_eq!(touched.len(), others.len());
    }

    #[test]
    fn striped_concurrent_push_pull_consistency() {
        // 4 writer threads on disjoint id ranges + pulls racing them; at
        // quiesce every id holds exactly its writer's accumulated state.
        let t = Arc::new(StripedSparseTable::new(
            "w",
            1,
            Arc::new(Sgd { lr: 1.0 }),
            1,
            8,
        ));
        let per = 500u64;
        let rounds = 20;
        let mut handles = Vec::new();
        for w in 0..4u64 {
            let t = t.clone();
            handles.push(std::thread::spawn(move || {
                let ids: Vec<u64> = (w * per..(w + 1) * per).collect();
                let grads = vec![-1.0f32; ids.len()];
                for _ in 0..rounds {
                    t.apply_batch(&ids, &grads, 0);
                    let mut out = vec![0.0f32; ids.len()];
                    t.pull_slot(&ids, "w", 0, &mut out).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(t.len(), 4 * per as usize);
        let ids: Vec<u64> = (0..4 * per).collect();
        let mut out = vec![0.0f32; ids.len()];
        t.pull_slot(&ids, "w", 0, &mut out).unwrap();
        // SGD with lr 1.0 and grad -1.0 for `rounds` rounds => w == rounds.
        assert!(out.iter().all(|&v| v == rounds as f32), "lost updates under contention");
    }

    #[test]
    fn striped_grouped_snapshot_matches_flat_and_pool() {
        let t = striped(1, 8);
        let ids: Vec<u64> = (0..500).collect();
        t.apply_batch(&ids, &vec![0.2f32; ids.len() * 2], 5);
        let mut groups: Vec<Vec<u64>> = vec![Vec::new(); t.stripe_count()];
        for &id in &ids {
            groups[t.stripe_of(id)].push(id);
        }
        let flat = t.read_rows(&ids);
        let seq = t.read_rows_grouped(&groups, None);
        let pool = ThreadPool::new(4, "snap");
        let par = t.read_rows_grouped(&groups, Some(&pool));
        assert_eq!(seq, par, "pooled snapshot diverged from sequential");
        let merged: RowSnapshot = seq.into_iter().flatten().collect();
        assert_eq!(merged, flat, "grouped snapshot diverged from flat read_rows");
        // Missing ids read back None through the grouped path too.
        let mut missing: Vec<Vec<u64>> = vec![Vec::new(); t.stripe_count()];
        missing[t.stripe_of(1_000_000)].push(1_000_000);
        let snap = t.read_rows_grouped(&missing, Some(&pool));
        assert!(snap.iter().flatten().all(|(_, r)| r.is_none()));
    }

    #[test]
    fn striped_expire_pooled_matches_sequential() {
        let pool = ThreadPool::new(4, "expire");
        let build = || {
            let t = striped(1, 8);
            t.apply_batch(&(0..100u64).collect::<Vec<_>>(), &vec![1.0f32; 200], 1_000);
            t.apply_batch(&(100..200u64).collect::<Vec<_>>(), &vec![1.0f32; 200], 9_000);
            t
        };
        let a = build();
        let b = build();
        let dead_seq = a.expire_pooled(10_000, 5_000, None);
        let dead_par = b.expire_pooled(10_000, 5_000, Some(&pool));
        assert_eq!(dead_seq, dead_par, "pooled expire order diverged");
        let mut sorted = dead_par.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100u64).collect::<Vec<_>>());
        assert_eq!(a.len(), 100);
        assert_eq!(b.len(), 100);
    }

    #[test]
    fn striped_apply_grouped_matches_per_row_and_last_write_wins() {
        let per_row = striped(1, 8);
        let grouped = striped(1, 8);
        // Two "batches" over overlapping ids: second overwrites ids 0..50
        // and deletes every 10th id.
        let first: Vec<(u64, Vec<f32>)> =
            (0..100u64).map(|id| (id, vec![id as f32, 1.0, 2.0, 3.0, 4.0, 5.0])).collect();
        let second: Vec<(u64, Option<Vec<f32>>)> = (0..50u64)
            .map(|id| {
                if id % 10 == 0 {
                    (id, None)
                } else {
                    (id, Some(vec![-(id as f32), 0.0, 0.0, 0.0, 0.0, 9.0]))
                }
            })
            .collect();
        // Per-row reference application.
        for (id, v) in &first {
            per_row.upsert_row(*id, v, 7).unwrap();
        }
        for (id, op) in &second {
            match op {
                Some(v) => per_row.upsert_row(*id, v, 8).unwrap(),
                None => {
                    per_row.delete(*id);
                }
            }
        }
        // Grouped application: both batches folded into one run.
        let mut groups: Vec<Vec<(u64, Option<&[f32]>)>> =
            vec![Vec::new(); grouped.stripe_count()];
        for (id, v) in &first {
            groups[grouped.stripe_of(*id)].push((*id, Some(v.as_slice())));
        }
        for (id, op) in &second {
            groups[grouped.stripe_of(*id)].push((*id, op.as_deref()));
        }
        let touched = grouped.apply_grouped(&groups, 8).unwrap();
        assert!(touched > 0);
        assert_eq!(per_row.len(), grouped.len());
        for id in 0..100u64 {
            assert_eq!(
                per_row.get_row(id).map(|r| r.values.clone()),
                grouped.get_row(id).map(|r| r.values.clone()),
                "id {id}"
            );
        }
        // Width mismatch: error surfaces, valid ops still land.
        let mut bad: Vec<Vec<(u64, Option<&[f32]>)>> = vec![Vec::new(); grouped.stripe_count()];
        let good_row = [1.0f32; 6];
        let short_row = [1.0f32; 2];
        bad[grouped.stripe_of(500)].push((500, Some(&good_row)));
        bad[grouped.stripe_of(501)].push((501, Some(&short_row)));
        assert!(grouped.apply_grouped(&bad, 9).is_err());
        assert!(grouped.get_row(500).is_some());
        assert!(grouped.get_row(501).is_none());
    }

    #[test]
    fn striped_upsert_delete_and_batched_kernel_path() {
        let t = striped(1, 4);
        assert!(t.upsert_row(9, &[1., 2., 3., 4., 5., 6.], 0).is_ok());
        assert!(t.upsert_row(9, &[0.0; 4], 0).is_err()); // wrong width
        assert_eq!(&*t.get_row(9).unwrap().values, &[1., 2., 3., 4., 5., 6.]);
        assert!(t.delete(9));
        assert!(!t.delete(9));

        // apply_batch_with mirrors the scalar path when the closure runs
        // the same FTRL math; with min_kernel_rows = 1 every group takes
        // the kernel closure.
        let scalar = striped(1, 1);
        let hp = FtrlHyper::default();
        let ids: Vec<u64> = (0..50).collect();
        let grads: Vec<f32> = (0..100).map(|i| (i as f32).sin()).collect();
        scalar.apply_batch(&ids, &grads, 7);
        let kernel_side = striped(1, 8);
        let ftrl = Ftrl::new(hp);
        let mut touched = Vec::new();
        let kernel_rows = kernel_side
            .apply_batch_with(&ids, &grads, 7, 1, &mut touched, |g, z, n, w| {
                let dim = 2;
                let k = g.len() / dim;
                for i in 0..k {
                    let mut row = vec![0.0f32; 3 * dim];
                    row[..dim].copy_from_slice(&z[i * dim..(i + 1) * dim]);
                    row[dim..2 * dim].copy_from_slice(&n[i * dim..(i + 1) * dim]);
                    ftrl.apply(&mut row, &g[i * dim..(i + 1) * dim], dim, 1);
                    z[i * dim..(i + 1) * dim].copy_from_slice(&row[..dim]);
                    n[i * dim..(i + 1) * dim].copy_from_slice(&row[dim..2 * dim]);
                    w[i * dim..(i + 1) * dim].copy_from_slice(&row[2 * dim..]);
                }
                Ok(())
            })
            .unwrap();
        assert_eq!(touched.len(), ids.len());
        assert_eq!(kernel_rows, ids.len() as u64);
        for &id in &ids {
            assert_eq!(
                kernel_side.get_row(id).unwrap().values,
                scalar.get_row(id).unwrap().values,
                "id {id}"
            );
        }

        // Groups below min_kernel_rows take the built-in scalar path and
        // produce identical state without invoking the closure.
        let fallback = striped(1, 8);
        let mut touched2 = Vec::new();
        let kernel_rows2 = fallback
            .apply_batch_with(&ids, &grads, 7, 1_000_000, &mut touched2, |_, _, _, _| {
                panic!("kernel must not run below the crossover")
            })
            .unwrap();
        assert_eq!(kernel_rows2, 0);
        assert_eq!(touched2.len(), ids.len());
        for &id in &ids {
            assert_eq!(
                fallback.get_row(id).unwrap().values,
                scalar.get_row(id).unwrap().values,
                "fallback id {id}"
            );
        }
    }

    // -- dirty-epoch tracking -------------------------------------------------

    #[test]
    fn epoch_delta_tracks_dirty_rows_and_tombstones() {
        let t = striped(1, 8);
        let ids: Vec<u64> = (0..100).collect();
        t.apply_batch(&ids, &vec![1.0f32; 200], 10);
        // Everything is dirty relative to epoch 0 (tables start at 1).
        let (up, del) = t.collect_delta(0);
        assert_eq!(up.len(), 100);
        assert!(del.is_empty());
        assert_eq!(t.dirty_counts(0), (100, 0));
        // Cut: nothing is dirty since epoch 1 any more.
        t.set_write_epoch(2);
        assert_eq!(t.dirty_counts(1), (0, 0));
        // Touch two rows and delete one: exactly those collect.
        t.apply_batch(&[3, 5], &[0.5, 0.5, 0.5, 0.5], 20);
        assert!(t.delete(7));
        let (up, del) = t.collect_delta(1);
        assert_eq!(up.iter().map(|r| r.id).collect::<Vec<_>>(), vec![3, 5]);
        assert_eq!(del, vec![7]);
        // Row metadata travels with the delta.
        assert!(up.iter().all(|r| r.updates == 2 && r.last_access_ms == 20));
        // Re-inserting a deleted id clears its tombstone.
        t.apply_batch(&[7], &[1.0, 1.0], 30);
        let (up, del) = t.collect_delta(1);
        assert_eq!(up.iter().map(|r| r.id).collect::<Vec<_>>(), vec![3, 5, 7]);
        assert!(del.is_empty());
        // Prune drops sealed tombstones, keeps newer ones.
        t.delete(5);
        t.set_write_epoch(3);
        t.delete(3);
        t.prune_graves(2);
        let (up, del) = t.collect_delta(2);
        assert!(up.is_empty());
        assert_eq!(del, vec![3]);
    }

    #[test]
    fn delta_round_trip_restores_identical_bytes() {
        let src = striped(1, 4);
        let ids: Vec<u64> = (0..50).collect();
        src.apply_batch(&ids, &vec![1.0f32; 100], 11);
        // Bootstrap the destination from a full snapshot (the base).
        let mut w = Writer::new();
        src.encode_rows(&mut w);
        let dst = striped(1, 16); // different stripe count on purpose
        dst.decode_rows(&mut Reader::new(&w.into_bytes())).unwrap();
        // Post-cut mutations: two updates and a delete.
        src.set_write_epoch(2);
        src.apply_batch(&[1, 2], &[2.0, 2.0, 2.0, 2.0], 22);
        src.delete(4);
        let mut dw = Writer::new();
        let (ups, dels) = src.encode_delta_rows(1, &mut dw);
        assert_eq!((ups, dels), (2, 1));
        let bytes = dw.into_bytes();
        dst.decode_delta_rows(&mut Reader::new(&bytes), 0).unwrap();
        // Full snapshots are now byte-identical (values *and* metadata).
        let mut a = Writer::new();
        src.encode_rows(&mut a);
        let mut b = Writer::new();
        dst.encode_rows(&mut b);
        assert_eq!(a.into_bytes(), b.into_bytes(), "delta restore diverged from source");
        // Hostile input: a truncated delta section errors, never panics.
        let cut = &bytes[..bytes.len() / 2];
        let fresh = striped(1, 4);
        assert!(fresh.decode_delta_rows(&mut Reader::new(cut), 0).is_err());
        // Schema mismatch is rejected.
        let wrong = StripedSparseTable::new(
            "w",
            4,
            Arc::new(Ftrl::new(FtrlHyper::default())),
            1,
            4,
        );
        assert!(wrong.decode_delta_rows(&mut Reader::new(&bytes), 0).is_err());
    }

    #[test]
    fn delta_collection_is_deterministic_across_stripe_counts() {
        let mut blobs = Vec::new();
        for stripes in [1usize, 4, 32] {
            let t = striped(1, stripes);
            let ids: Vec<u64> = (0..300).collect();
            t.apply_batch(&ids, &vec![0.25f32; 600], 5);
            t.set_write_epoch(2);
            t.apply_batch(&(0..40u64).collect::<Vec<_>>(), &vec![0.5f32; 80], 6);
            t.delete(50);
            t.delete(51);
            let mut w = Writer::new();
            t.encode_delta_rows(1, &mut w);
            blobs.push(w.into_bytes());
        }
        for b in &blobs[1..] {
            assert_eq!(b, &blobs[0], "delta bytes differ across stripe counts");
        }
    }

    #[test]
    fn grave_tracking_off_leaves_no_tombstones() {
        let t = striped(1, 4);
        t.apply_batch(&[1, 2], &[1.0, 1.0, 1.0, 1.0], 0);
        t.set_grave_tracking(false);
        assert!(t.delete(1));
        assert_eq!(t.expire(10_000, 5_000), vec![2]);
        assert_eq!(t.dirty_counts(0).1, 0, "graves recorded while tracking is off");
        let (_, deletes) = t.collect_delta(0);
        assert!(deletes.is_empty());
    }

    #[test]
    fn restore_row_preserves_metadata_and_stamp() {
        let t = striped(1, 4);
        t.restore_row(9, &[1., 2., 3., 4., 5., 6.], 77, 13, 0).unwrap();
        let row = t.get_row(9).unwrap();
        assert_eq!(row.last_access_ms, 77);
        assert_eq!(row.updates, 13);
        assert_eq!(row.epoch, 0);
        // Clean stamp: not collected as dirty.
        assert_eq!(t.dirty_counts(0), (0, 0));
        // Dirty stamp: collected.
        t.restore_row(10, &[0.0; 6], 1, 1, 5).unwrap();
        let (up, _) = t.collect_delta(4);
        assert_eq!(up.iter().map(|r| r.id).collect::<Vec<_>>(), vec![10]);
        // Width mismatch errors cleanly.
        assert!(t.restore_row(11, &[0.0; 2], 0, 0, 0).is_err());
    }

    #[test]
    fn pull_slot_access_refresh_is_epoch_stamped() {
        let t = striped(1, 4);
        let ids: Vec<u64> = (0..20).collect();
        t.apply_batch(&ids, &vec![1.0f32; 40], 10);
        // Seal the write window: nothing is dirty afterwards.
        t.set_write_epoch(2);
        assert_eq!(t.dirty_counts(1), (0, 0));
        // A pull at a *new* timestamp refreshes access times and dirties
        // exactly the touched rows, so the freshness survives recovery.
        let mut out = vec![0.0f32; 4];
        t.pull_slot(&[3, 7], "w", 99, &mut out).unwrap();
        let (up, del) = t.collect_delta(1);
        assert_eq!(up.iter().map(|r| r.id).collect::<Vec<_>>(), vec![3, 7]);
        assert!(del.is_empty());
        assert!(up.iter().all(|r| r.last_access_ms == 99));
        // Same-timestamp re-pull does not re-stamp (coarse dedup).
        t.set_write_epoch(3);
        t.pull_slot(&[3], "w", 99, &mut out[..2]).unwrap();
        assert_eq!(t.dirty_counts(2), (0, 0));
        // The refreshed access time round-trips through a delta restore,
        // so expire fidelity is preserved after recovery.
        let dst = striped(1, 8);
        let mut w = Writer::new();
        t.encode_delta_rows(0, &mut w);
        dst.decode_delta_rows(&mut Reader::new(&w.into_bytes()), 0).unwrap();
        assert_eq!(dst.get_row(3).unwrap().last_access_ms, 99);
        let evicted = dst.expire(100, 50);
        // Everything except the two refreshed rows ages out at ttl 50.
        assert_eq!(evicted.len(), 18);
        assert_eq!(dst.len(), 2);
        // Migration catch-up tracks *values* only: access-time refreshes
        // are never re-streamed (catch-up must converge under reads).
        let full = crate::reshard::SlotSet::full(16);
        let (up, del) = t.collect_slot_delta(Some(1), &full);
        assert!(up.is_empty() && del.is_empty(), "access refresh leaked into slot delta");
    }

    #[test]
    fn slot_delta_collects_filtered_and_purge_is_silent() {
        use crate::reshard::{slot_of, SlotSet};
        let t = striped(1, 4);
        let ids: Vec<u64> = (0..200).collect();
        t.apply_batch(&ids, &vec![1.0f32; 400], 5);
        let universe = 16usize;
        let moved = SlotSet::from_slots(&[1, 5, 9], universe).unwrap();
        let expect: Vec<u64> =
            ids.iter().copied().filter(|&id| moved.contains(slot_of(id, universe))).collect();
        assert!(!expect.is_empty() && expect.len() < ids.len());
        // Base pass (since = None) takes every row in the slots, even
        // clean ones (epoch 0 after a restore).
        t.restore_row(expect[0], &[9.0; 6], 1, 1, 0).unwrap();
        let (up, del) = t.collect_slot_delta(None, &moved);
        assert_eq!(up.len(), expect.len());
        assert!(del.is_empty());
        assert!(up.windows(2).all(|w| w[0].id < w[1].id), "not sorted");
        // Catch-up pass: only post-cut mutations in the slots.
        t.set_write_epoch(2);
        t.apply_batch(&ids[..50], &vec![0.5f32; 100], 6);
        t.delete(expect[1]);
        let (up, del) = t.collect_slot_delta(Some(1), &moved);
        assert!(up.iter().all(|r| moved.contains(slot_of(r.id, universe)) && r.id < 50));
        assert_eq!(del, vec![expect[1]]);
        // Wire shape matches decode_delta_rows.
        let mut w = Writer::new();
        let (nu, nd) = t.encode_slot_delta_rows(Some(1), &moved, &mut w);
        assert_eq!((nu, nd), (up.len(), del.len()));
        let dst = striped(1, 8);
        let (au, _) = dst.decode_delta_rows(&mut Reader::new(&w.into_bytes()), 5).unwrap();
        assert_eq!(au, nu);
        // Purge: rows gone, no tombstones, nothing dirty left behind.
        let before_graves = t.dirty_counts(0).1;
        let purged = t.purge_slots(&moved);
        assert_eq!(purged, expect.len() - 1); // one was deleted above
        assert_eq!(t.len(), ids.len() - expect.len());
        let (_, graves_after) = t.dirty_counts(0);
        assert!(graves_after <= before_graves, "purge left tombstones");
        let (up, del) = t.collect_slot_delta(None, &moved);
        assert!(up.is_empty() && del.is_empty(), "purged slots still collect");
    }

    // -- row-store backends ---------------------------------------------------

    fn striped_store(store: RowStore, threshold: u32, stripes: usize) -> StripedSparseTable {
        StripedSparseTable::with_row_store(
            "w",
            2,
            Arc::new(Ftrl::new(FtrlHyper::default())),
            threshold,
            stripes,
            store,
        )
    }

    #[test]
    fn row_store_parses_config_strings() {
        assert_eq!(RowStore::parse("arena").unwrap(), RowStore::Arena);
        assert_eq!(RowStore::parse("boxed").unwrap(), RowStore::Boxed);
        assert!(RowStore::parse("slab").is_err());
        assert_eq!(striped(1, 4).row_store(), RowStore::Arena); // default
    }

    #[test]
    fn arena_and_boxed_tables_are_byte_identical() {
        // The same op sequence through both backings — pushes through the
        // entry filter, upserts, restores, deletes, access-stamping pulls
        // — must produce byte-identical snapshots and delta chunks.
        let run = |store: RowStore| {
            let t = striped_store(store, 2, 8);
            let ids: Vec<u64> = (0..300).collect();
            let grads: Vec<f32> = (0..600).map(|i| (i as f32 * 0.37).sin()).collect();
            t.apply_batch(&ids, &grads, 10);
            t.apply_batch(&ids, &grads, 11); // second pass clears probation
            t.apply_batch(&ids[..90], &grads[..180], 12);
            for id in 0..40u64 {
                t.upsert_row(id * 3, &[id as f32; 6], 13).unwrap();
            }
            t.restore_row(7_000, &[1., 2., 3., 4., 5., 6.], 20, 4, 0).unwrap();
            for id in 0..30u64 {
                t.delete(id * 5);
            }
            let mut out = vec![0.0f32; 100 * 2];
            t.pull_slot(&ids[..100], "w", 99, &mut out).unwrap();
            t.set_write_epoch(2);
            t.apply_batch(&ids[40..80], &grads[80..160], 100);
            t
        };
        let arena = run(RowStore::Arena);
        let boxed = run(RowStore::Boxed);
        assert_eq!(arena.len(), boxed.len());
        let mut a = Writer::new();
        arena.encode_rows(&mut a);
        let mut b = Writer::new();
        boxed.encode_rows(&mut b);
        let snapshot = a.into_bytes();
        assert_eq!(snapshot, b.into_bytes(), "snapshot bytes diverge across row stores");
        let mut da = Writer::new();
        arena.encode_delta_rows(1, &mut da);
        let mut db = Writer::new();
        boxed.encode_delta_rows(1, &mut db);
        assert_eq!(da.into_bytes(), db.into_bytes(), "delta bytes diverge across row stores");
        // Pull outputs agree too.
        let ids: Vec<u64> = (0..300).collect();
        let mut pa = vec![0.0f32; 600];
        let mut pb = vec![0.0f32; 600];
        arena.pull_slot(&ids, "z", 200, &mut pa).unwrap();
        boxed.pull_slot(&ids, "z", 200, &mut pb).unwrap();
        assert_eq!(pa, pb);
        // Arena rows really live in the arena; clones escaping the lock
        // are always owned.
        let s = arena.stripes[arena.stripe_of(1)].read().unwrap();
        assert!(s.rows.get(&1).unwrap().values.is_arena_backed());
        drop(s);
        assert!(!arena.get_row(1).unwrap().values.is_arena_backed());
        // The bytes decode into either backing and re-encode identically.
        for store in [RowStore::Arena, RowStore::Boxed] {
            let t = striped_store(store, 2, 4);
            t.decode_rows(&mut Reader::new(&snapshot)).unwrap();
            let mut rw = Writer::new();
            t.encode_rows(&mut rw);
            assert_eq!(rw.into_bytes(), snapshot, "{store:?} re-encode diverged");
        }
    }

    #[test]
    fn arena_compaction_reclaims_waste_and_preserves_state() {
        // One stripe so the waste ratio is deterministic.
        let t = striped_store(RowStore::Arena, 1, 1);
        let ids: Vec<u64> = (0..400).collect();
        t.apply_batch(&ids, &vec![1.0f32; 800], 1_000);
        assert_eq!(t.arena_waste_floats(), 0);
        for id in 0..200u64 {
            t.delete(id);
        }
        let waste = t.arena_waste_floats();
        assert_eq!(waste, 200 * 6, "deletes left unexpected waste: {waste}");
        let mut before = Writer::new();
        t.encode_rows(&mut before);
        let before = before.into_bytes();
        // Expire evicts nothing (everything is fresh) but the sweep still
        // compacts the stranded half of the arena.
        let dead = t.expire(1_500, 10_000);
        assert!(dead.is_empty());
        assert_eq!(t.arena_waste_floats(), 0, "expire sweep did not compact");
        let mut after = Writer::new();
        t.encode_rows(&mut after);
        assert_eq!(after.into_bytes(), before, "compaction changed table bytes");
        // Rows still read correctly after the pointer rewrite.
        let live: Vec<u64> = (200..400).collect();
        let mut out = vec![0.0f32; live.len() * 2];
        t.pull_slot(&live, "z", 1_001, &mut out).unwrap();
        for pair in out.chunks(2) {
            assert_eq!(pair, &[1.0, 1.0]); // z = g on first update
        }
        // Eviction-driven waste is reclaimed in the same sweep.
        let dead = t.expire(20_000, 5_000);
        assert_eq!(dead.len(), live.len());
        assert_eq!(t.len(), 0);
        assert_eq!(t.arena_waste_floats(), 0, "post-eviction arena not reclaimed");
    }

    #[test]
    fn prop_arena_and_boxed_stay_byte_identical_under_random_ops() {
        use crate::util::prop::{check, PairOf, U64Range, VecOf};
        check(
            "arena-boxed-identity",
            &VecOf(PairOf(U64Range(0, 5), U64Range(0, 60)), 80),
            40,
            |ops| {
                let arena = striped_store(RowStore::Arena, 1, 4);
                let boxed = striped_store(RowStore::Boxed, 1, 4);
                for (i, &(kind, id)) in ops.iter().enumerate() {
                    let now = 1 + i as u64;
                    let g = [(id as f32) * 0.1 - 1.0, (i as f32) * 0.01];
                    for t in [&arena, &boxed] {
                        match kind {
                            0 | 1 => {
                                t.apply_batch(&[id], &g, now);
                            }
                            2 => {
                                t.upsert_row(id, &[g[0]; 6], now).unwrap();
                            }
                            3 => {
                                t.delete(id);
                            }
                            4 => {
                                let mut out = [0.0f32; 2];
                                t.pull_slot(&[id], "w", now, &mut out).unwrap();
                            }
                            _ => {
                                let _ = t.expire(now, 20);
                            }
                        }
                    }
                }
                let mut a = Writer::new();
                arena.encode_rows(&mut a);
                let mut b = Writer::new();
                boxed.encode_rows(&mut b);
                if a.into_bytes() != b.into_bytes() {
                    return Err("snapshot bytes diverged across row stores".into());
                }
                Ok(())
            },
        );
    }
}
