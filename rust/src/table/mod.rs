//! Parameter tables: sharded sparse slot-tables and dense tensors.
//!
//! A [`SparseTable`] holds the rows of one logical parameter matrix on one
//! server shard (id → `slots × dim` f32s, slot layout owned by the
//! optimizer). It implements the XDL-derived features the paper adopts
//! (§2.2, §4.1c): **feature entry filter** (rows materialize only after an
//! id has been observed `entry_threshold` times — low-frequency junk never
//! allocates) and **feature expire** (ids untouched for a TTL are evicted,
//! and the eviction propagates to slaves through sync deletes).
//!
//! Tables are deliberately lock-free-free: a shard server wraps its tables
//! in the shard's own `RwLock` — no double locking on the hot path.

use crate::codec::{Encode, Reader, Writer};
use crate::optim::Optimizer;
use crate::util::hash::FxHashMap;
use crate::{Error, Result};
use std::sync::Arc;

/// One sparse row.
#[derive(Debug, Clone, PartialEq)]
pub struct Row {
    pub values: Box<[f32]>,
    pub last_access_ms: u64,
    pub updates: u32,
}

/// Sparse parameter table (one shard's slice of one matrix).
pub struct SparseTable {
    name: String,
    dim: usize,
    optimizer: Arc<dyn Optimizer>,
    rows: FxHashMap<u64, Row>,
    /// Entry filter: ids seen fewer than `entry_threshold` times live here.
    probation: FxHashMap<u64, u32>,
    entry_threshold: u32,
}

impl SparseTable {
    /// New table; `entry_threshold = 1` materializes rows immediately.
    pub fn new(
        name: impl Into<String>,
        dim: usize,
        optimizer: Arc<dyn Optimizer>,
        entry_threshold: u32,
    ) -> SparseTable {
        SparseTable {
            name: name.into(),
            dim,
            optimizer,
            rows: FxHashMap::default(),
            probation: FxHashMap::default(),
            entry_threshold: entry_threshold.max(1),
        }
    }

    /// Table name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Per-slot dimension.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Optimizer owning the slot layout.
    pub fn optimizer(&self) -> &Arc<dyn Optimizer> {
        &self.optimizer
    }

    /// Materialized row count.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when no rows are materialized.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Approximate bytes held (rows only).
    pub fn bytes(&self) -> usize {
        self.rows.len() * (self.optimizer.row_width(self.dim) * 4 + 24)
    }

    fn row_width(&self) -> usize {
        self.optimizer.row_width(self.dim)
    }

    /// Read one slot (by name) for `ids` into `out` (missing ids → 0.0).
    /// `out.len() == ids.len() * dim`. Updates access times.
    pub fn pull_slot(&mut self, ids: &[u64], slot: &str, now_ms: u64, out: &mut [f32]) -> Result<()> {
        let dim = self.dim;
        debug_assert_eq!(out.len(), ids.len() * dim);
        let slot_idx = self
            .optimizer
            .slot_index(slot)
            .ok_or_else(|| Error::NotFound(format!("slot {slot} in table {}", self.name)))?;
        for (i, id) in ids.iter().enumerate() {
            let dst = &mut out[i * dim..(i + 1) * dim];
            match self.rows.get_mut(id) {
                Some(row) => {
                    row.last_access_ms = now_ms;
                    dst.copy_from_slice(&row.values[slot_idx * dim..(slot_idx + 1) * dim]);
                }
                None => dst.fill(0.0),
            }
        }
        Ok(())
    }

    /// Full row for `id` (no access-time touch).
    pub fn get_row(&self, id: u64) -> Option<&Row> {
        self.rows.get(&id)
    }

    /// Apply pre-aggregated gradients: `grads.len() == ids.len() * dim`,
    /// ids must be unique (aggregate duplicates upstream — see
    /// [`aggregate_grads`]). Returns the ids whose rows changed (i.e.
    /// passed the entry filter) for the sync collector.
    pub fn apply_grads(&mut self, ids: &[u64], grads: &[f32], now_ms: u64) -> Vec<u64> {
        debug_assert_eq!(grads.len(), ids.len() * self.dim);
        let dim = self.dim;
        let width = self.row_width();
        let mut touched = Vec::with_capacity(ids.len());
        for (i, &id) in ids.iter().enumerate() {
            if !self.rows.contains_key(&id) {
                // Entry filter: count observations until the threshold.
                let seen = self.probation.entry(id).or_insert(0);
                *seen += 1;
                if *seen < self.entry_threshold {
                    continue;
                }
                self.probation.remove(&id);
                self.rows.insert(
                    id,
                    Row {
                        values: vec![0.0; width].into_boxed_slice(),
                        last_access_ms: now_ms,
                        updates: 0,
                    },
                );
            }
            let row = self.rows.get_mut(&id).unwrap();
            row.updates += 1;
            row.last_access_ms = now_ms;
            self.optimizer
                .apply(&mut row.values, &grads[i * dim..(i + 1) * dim], dim, row.updates);
            touched.push(id);
        }
        touched
    }

    /// Run `ids` through the entry filter, materializing rows that pass.
    /// Returns the subset of `ids` (with positions) that are materialized
    /// and may be updated. Order of first occurrence is preserved.
    pub fn ensure_rows(&mut self, ids: &[u64], now_ms: u64) -> Vec<(usize, u64)> {
        let width = self.row_width();
        let mut ready = Vec::with_capacity(ids.len());
        for (pos, &id) in ids.iter().enumerate() {
            if !self.rows.contains_key(&id) {
                let seen = self.probation.entry(id).or_insert(0);
                *seen += 1;
                if *seen < self.entry_threshold {
                    continue;
                }
                self.probation.remove(&id);
                self.rows.insert(
                    id,
                    Row {
                        values: vec![0.0; width].into_boxed_slice(),
                        last_access_ms: now_ms,
                        updates: 0,
                    },
                );
            }
            ready.push((pos, id));
        }
        ready
    }

    /// Gather two slots (by index) for materialized `ids` into flat
    /// `(a, b)` arrays of `ids.len() * dim` — the batched-FTRL read path
    /// (slots z and n). Missing rows panic (call [`Self::ensure_rows`]).
    pub fn gather_slot_pair(&self, ids: &[u64], slot_a: usize, slot_b: usize, a: &mut [f32], b: &mut [f32]) {
        let dim = self.dim;
        for (i, id) in ids.iter().enumerate() {
            let row = self.rows.get(id).expect("gather of unmaterialized row");
            a[i * dim..(i + 1) * dim]
                .copy_from_slice(&row.values[slot_a * dim..(slot_a + 1) * dim]);
            b[i * dim..(i + 1) * dim]
                .copy_from_slice(&row.values[slot_b * dim..(slot_b + 1) * dim]);
        }
    }

    /// Scatter three slots back for `ids` (batched-FTRL write path: z, n,
    /// w), bumping update counts and access times.
    pub fn scatter_slot_triple(
        &mut self,
        ids: &[u64],
        slots: (usize, usize, usize),
        a: &[f32],
        b: &[f32],
        c: &[f32],
        now_ms: u64,
    ) {
        let dim = self.dim;
        for (i, id) in ids.iter().enumerate() {
            let row = self.rows.get_mut(id).expect("scatter to unmaterialized row");
            row.values[slots.0 * dim..(slots.0 + 1) * dim]
                .copy_from_slice(&a[i * dim..(i + 1) * dim]);
            row.values[slots.1 * dim..(slots.1 + 1) * dim]
                .copy_from_slice(&b[i * dim..(i + 1) * dim]);
            row.values[slots.2 * dim..(slots.2 + 1) * dim]
                .copy_from_slice(&c[i * dim..(i + 1) * dim]);
            row.updates += 1;
            row.last_access_ms = now_ms;
        }
    }

    /// Overwrite a full row (scatter / checkpoint-load path).
    pub fn upsert_row(&mut self, id: u64, values: &[f32], now_ms: u64) -> Result<()> {
        if values.len() != self.row_width() {
            return Err(Error::Codec(format!(
                "row width {} != {} for table {}",
                values.len(),
                self.row_width(),
                self.name
            )));
        }
        match self.rows.get_mut(&id) {
            Some(row) => {
                row.values.copy_from_slice(values);
                row.last_access_ms = now_ms;
            }
            None => {
                self.rows.insert(
                    id,
                    Row {
                        values: values.to_vec().into_boxed_slice(),
                        last_access_ms: now_ms,
                        updates: 0,
                    },
                );
            }
        }
        Ok(())
    }

    /// Remove a row; true if it existed.
    pub fn delete(&mut self, id: u64) -> bool {
        self.probation.remove(&id);
        self.rows.remove(&id).is_some()
    }

    /// Feature expire: evict rows untouched for `ttl_ms`; returns evicted
    /// ids (propagated to slaves as sync deletes).
    pub fn expire(&mut self, now_ms: u64, ttl_ms: u64) -> Vec<u64> {
        let dead: Vec<u64> = self
            .rows
            .iter()
            .filter(|(_, r)| now_ms.saturating_sub(r.last_access_ms) > ttl_ms)
            .map(|(id, _)| *id)
            .collect();
        for id in &dead {
            self.rows.remove(id);
        }
        // Probation entries also age out wholesale on expire passes.
        self.probation.clear();
        dead
    }

    /// Iterate all materialized rows.
    pub fn iter(&self) -> impl Iterator<Item = (&u64, &Row)> {
        self.rows.iter()
    }

    /// Serialize every row (checkpoint shard payload).
    pub fn encode_rows(&self, w: &mut Writer) {
        w.put_str(&self.name);
        w.put_u32(self.dim as u32);
        w.put_u32(self.row_width() as u32);
        w.put_varint(self.rows.len() as u64);
        for (id, row) in &self.rows {
            w.put_varint(*id);
            w.put_varint(row.last_access_ms);
            w.put_u32(row.updates);
            w.put_f32_slice(&row.values);
        }
    }

    /// Restore rows from a checkpoint (replaces current content).
    pub fn decode_rows(&mut self, r: &mut Reader) -> Result<()> {
        let name = r.get_str()?;
        if name != self.name {
            return Err(Error::Checkpoint(format!(
                "checkpoint table {name} != {}",
                self.name
            )));
        }
        let dim = r.get_u32()? as usize;
        let width = r.get_u32()? as usize;
        if dim != self.dim || width != self.row_width() {
            return Err(Error::Checkpoint(format!(
                "table {} schema mismatch: dim {dim}/{} width {width}/{}",
                self.name,
                self.dim,
                self.row_width()
            )));
        }
        let count = r.get_varint()? as usize;
        self.rows.clear();
        self.probation.clear();
        for _ in 0..count {
            let id = r.get_varint()?;
            let last_access_ms = r.get_varint()?;
            let updates = r.get_u32()?;
            let values = r.get_f32_slice()?;
            if values.len() != width {
                return Err(Error::Checkpoint(format!(
                    "row {id} width {} != {width}",
                    values.len()
                )));
            }
            self.rows.insert(
                id,
                Row { values: values.into_boxed_slice(), last_access_ms, updates },
            );
        }
        Ok(())
    }
}

/// Aggregate duplicate ids in a push batch by summing their gradients.
/// Returns unique ids + summed grads (order of first occurrence).
pub fn aggregate_grads(ids: &[u64], grads: &[f32], dim: usize) -> (Vec<u64>, Vec<f32>) {
    debug_assert_eq!(grads.len(), ids.len() * dim);
    let mut index: FxHashMap<u64, usize> = FxHashMap::default();
    let mut out_ids = Vec::with_capacity(ids.len());
    let mut out_grads: Vec<f32> = Vec::with_capacity(grads.len());
    for (i, &id) in ids.iter().enumerate() {
        match index.get(&id) {
            Some(&pos) => {
                let dst = pos * dim;
                for j in 0..dim {
                    out_grads[dst + j] += grads[i * dim + j];
                }
            }
            None => {
                index.insert(id, out_ids.len());
                out_ids.push(id);
                out_grads.extend_from_slice(&grads[i * dim..(i + 1) * dim]);
            }
        }
    }
    (out_ids, out_grads)
}

// ---------------------------------------------------------------------------
// Dense tables
// ---------------------------------------------------------------------------

/// Dense optimizer for tower weights (SGD or Adagrad with internal state).
#[derive(Debug, Clone)]
pub enum DenseOpt {
    Sgd { lr: f32 },
    Adagrad { lr: f32, eps: f32 },
}

/// A dense parameter tensor (MLP tower weights, bias) with optimizer state.
pub struct DenseTable {
    name: String,
    values: Vec<f32>,
    acc: Vec<f32>,
    opt: DenseOpt,
    /// Bumped on every update; slaves use it to detect staleness.
    pub version: u64,
}

impl DenseTable {
    /// New dense table with `init` values.
    pub fn new(name: impl Into<String>, init: Vec<f32>, opt: DenseOpt) -> DenseTable {
        let acc = vec![0.0; init.len()];
        DenseTable { name: name.into(), values: init, acc, opt, version: 0 }
    }

    /// Table name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Current values.
    pub fn values(&self) -> &[f32] {
        &self.values
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.values.len()
    }

    /// True when the table is empty.
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Apply a gradient of the same length.
    pub fn apply_grad(&mut self, grad: &[f32]) -> Result<()> {
        if grad.len() != self.values.len() {
            return Err(Error::Codec(format!(
                "dense grad len {} != {} for {}",
                grad.len(),
                self.values.len(),
                self.name
            )));
        }
        match self.opt {
            DenseOpt::Sgd { lr } => {
                for (w, g) in self.values.iter_mut().zip(grad) {
                    *w -= lr * g;
                }
            }
            DenseOpt::Adagrad { lr, eps } => {
                for ((w, a), g) in self.values.iter_mut().zip(&mut self.acc).zip(grad) {
                    *a += g * g;
                    *w -= lr * g / (a.sqrt() + eps);
                }
            }
        }
        self.version += 1;
        Ok(())
    }

    /// Overwrite values (scatter / checkpoint load).
    pub fn set_values(&mut self, values: &[f32]) -> Result<()> {
        if values.len() != self.values.len() {
            return Err(Error::Codec(format!(
                "dense set len {} != {} for {}",
                values.len(),
                self.values.len(),
                self.name
            )));
        }
        self.values.copy_from_slice(values);
        self.version += 1;
        Ok(())
    }
}

impl Encode for DenseTable {
    fn encode(&self, w: &mut Writer) {
        w.put_str(&self.name);
        w.put_u64(self.version);
        w.put_f32_slice(&self.values);
        w.put_f32_slice(&self.acc);
    }
}

impl DenseTable {
    /// Restore state saved by [`Encode::encode`] into this table.
    pub fn decode_into(&mut self, r: &mut Reader) -> Result<()> {
        let name = r.get_str()?;
        if name != self.name {
            return Err(Error::Checkpoint(format!("dense table {name} != {}", self.name)));
        }
        self.version = r.get_u64()?;
        let values = r.get_f32_slice()?;
        let acc = r.get_f32_slice()?;
        if values.len() != self.values.len() {
            return Err(Error::Checkpoint(format!(
                "dense {} len {} != {}",
                self.name,
                values.len(),
                self.values.len()
            )));
        }
        self.values = values;
        self.acc = acc;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{Ftrl, FtrlHyper, Sgd};

    fn table(threshold: u32) -> SparseTable {
        SparseTable::new("w", 2, Arc::new(Ftrl::new(FtrlHyper::default())), threshold)
    }

    #[test]
    fn pull_missing_ids_is_zero() {
        let mut t = table(1);
        let mut out = vec![9.0; 6];
        t.pull_slot(&[1, 2, 3], "w", 0, &mut out).unwrap();
        assert_eq!(out, vec![0.0; 6]);
        assert!(t.pull_slot(&[1], "nope", 0, &mut [0.0; 2]).is_err());
    }

    #[test]
    fn apply_then_pull_round_trips() {
        let mut t = table(1);
        let touched = t.apply_grads(&[7, 8], &[1.0, 1.0, -1.0, -1.0], 100);
        assert_eq!(touched, vec![7, 8]);
        assert_eq!(t.len(), 2);
        let mut z = vec![0.0; 2];
        t.pull_slot(&[7], "z", 100, &mut z).unwrap();
        assert_eq!(z, vec![1.0, 1.0]); // z = g on first update from zero
        let mut n = vec![0.0; 2];
        t.pull_slot(&[8], "n", 100, &mut n).unwrap();
        assert_eq!(n, vec![1.0, 1.0]); // n = g^2
    }

    #[test]
    fn entry_filter_defers_materialization() {
        let mut t = table(3);
        assert!(t.apply_grads(&[5], &[1.0, 1.0], 0).is_empty());
        assert!(t.apply_grads(&[5], &[1.0, 1.0], 0).is_empty());
        assert_eq!(t.len(), 0);
        // Third observation materializes and applies.
        assert_eq!(t.apply_grads(&[5], &[1.0, 1.0], 0), vec![5]);
        assert_eq!(t.len(), 1);
        assert_eq!(t.get_row(5).unwrap().updates, 1);
    }

    #[test]
    fn expire_evicts_stale_rows() {
        let mut t = table(1);
        t.apply_grads(&[1], &[1.0, 1.0], 1_000);
        t.apply_grads(&[2], &[1.0, 1.0], 5_000);
        let dead = t.expire(10_000, 6_000);
        assert_eq!(dead, vec![1]);
        assert_eq!(t.len(), 1);
        assert!(t.get_row(2).is_some());
        // Access refreshes the clock.
        let mut out = vec![0.0; 2];
        t.pull_slot(&[2], "w", 20_000, &mut out).unwrap();
        assert!(t.expire(24_000, 6_000).is_empty());
    }

    #[test]
    fn delete_removes_row_and_probation() {
        let mut t = table(2);
        t.apply_grads(&[9], &[1.0, 1.0], 0); // probation only
        assert!(!t.delete(9)); // not materialized
        t.apply_grads(&[9], &[1.0, 1.0], 0);
        t.apply_grads(&[9], &[1.0, 1.0], 0);
        assert_eq!(t.len(), 1);
        assert!(t.delete(9));
        assert_eq!(t.len(), 0);
    }

    #[test]
    fn upsert_validates_width() {
        let mut t = table(1);
        assert!(t.upsert_row(1, &[0.0; 6], 0).is_ok()); // 3 slots * dim 2
        assert!(t.upsert_row(1, &[0.0; 4], 0).is_err());
        t.upsert_row(1, &[1., 2., 3., 4., 5., 6.], 0).unwrap();
        assert_eq!(&*t.get_row(1).unwrap().values, &[1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn checkpoint_round_trip() {
        let mut t = table(1);
        for id in 0..100u64 {
            t.apply_grads(&[id], &[id as f32 * 0.1, -0.5], 50);
        }
        let mut w = Writer::new();
        t.encode_rows(&mut w);
        let bytes = w.into_bytes();

        let mut t2 = table(1);
        t2.decode_rows(&mut Reader::new(&bytes)).unwrap();
        assert_eq!(t2.len(), 100);
        for id in 0..100u64 {
            assert_eq!(t.get_row(id).unwrap(), t2.get_row(id).unwrap(), "row {id}");
        }
    }

    #[test]
    fn checkpoint_schema_mismatch_rejected() {
        let mut t = table(1);
        t.apply_grads(&[1], &[1.0, 1.0], 0);
        let mut w = Writer::new();
        t.encode_rows(&mut w);
        let bytes = w.into_bytes();
        // dim-4 table refuses a dim-2 checkpoint.
        let mut t4 = SparseTable::new("w", 4, Arc::new(Ftrl::new(FtrlHyper::default())), 1);
        assert!(t4.decode_rows(&mut Reader::new(&bytes)).is_err());
        // Different name refuses too.
        let mut tn = SparseTable::new("v", 2, Arc::new(Ftrl::new(FtrlHyper::default())), 1);
        assert!(tn.decode_rows(&mut Reader::new(&bytes)).is_err());
    }

    #[test]
    fn aggregate_grads_sums_duplicates() {
        let (ids, grads) = aggregate_grads(
            &[3, 5, 3, 5, 7],
            &[1., 1., 2., 2., 10., 10., 20., 20., 5., 5.],
            2,
        );
        assert_eq!(ids, vec![3, 5, 7]);
        assert_eq!(grads, vec![11., 11., 22., 22., 5., 5.]);
    }

    #[test]
    fn prop_aggregate_preserves_total_mass() {
        use crate::util::prop::{check, PairOf, U64Range, VecOf};
        check(
            "aggregate-mass",
            &VecOf(PairOf(U64Range(0, 9), U64Range(0, 100)), 64),
            200,
            |pairs| {
                let ids: Vec<u64> = pairs.iter().map(|(id, _)| *id).collect();
                let grads: Vec<f32> = pairs.iter().map(|(_, g)| *g as f32).collect();
                let (uids, ugrads) = aggregate_grads(&ids, &grads, 1);
                let total_in: f32 = grads.iter().sum();
                let total_out: f32 = ugrads.iter().sum();
                if (total_in - total_out).abs() > 1e-3 {
                    return Err(format!("mass {total_in} -> {total_out}"));
                }
                let mut sorted = uids.clone();
                sorted.sort();
                sorted.dedup();
                if sorted.len() != uids.len() {
                    return Err("duplicate ids in output".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn dense_sgd_and_adagrad() {
        let mut d = DenseTable::new("b", vec![1.0, 2.0], DenseOpt::Sgd { lr: 0.5 });
        d.apply_grad(&[1.0, -1.0]).unwrap();
        assert_eq!(d.values(), &[0.5, 2.5]);
        assert_eq!(d.version, 1);
        assert!(d.apply_grad(&[1.0]).is_err());

        let mut a = DenseTable::new("w1", vec![0.0; 2], DenseOpt::Adagrad { lr: 0.1, eps: 1e-8 });
        a.apply_grad(&[1.0, 1.0]).unwrap();
        let first = -a.values()[0];
        a.apply_grad(&[1.0, 1.0]).unwrap();
        let second = first - (-a.values()[0] - first) ; // step sizes shrink
        assert!(first > 0.0 && second > 0.0);
    }

    #[test]
    fn dense_checkpoint_round_trip() {
        let mut d = DenseTable::new("w1", vec![0.0; 8], DenseOpt::Adagrad { lr: 0.1, eps: 1e-8 });
        d.apply_grad(&[0.5; 8]).unwrap();
        d.apply_grad(&[-0.25; 8]).unwrap();
        let bytes = d.to_bytes();

        let mut d2 = DenseTable::new("w1", vec![0.0; 8], DenseOpt::Adagrad { lr: 0.1, eps: 1e-8 });
        d2.decode_into(&mut Reader::new(&bytes)).unwrap();
        assert_eq!(d2.values(), d.values());
        assert_eq!(d2.version, d.version);
        // Post-restore updates continue from restored adagrad state.
        d.apply_grad(&[0.1; 8]).unwrap();
        d2.apply_grad(&[0.1; 8]).unwrap();
        assert_eq!(d.values(), d2.values());
    }

    #[test]
    fn sgd_table_slot_layout() {
        let mut t = SparseTable::new("w", 4, Arc::new(Sgd { lr: 0.1 }), 1);
        t.apply_grads(&[1], &[1.0, 2.0, 3.0, 4.0], 0);
        let row = t.get_row(1).unwrap();
        assert_eq!(row.values.len(), 4); // single slot
        assert_eq!(&*row.values, &[-0.1, -0.2, -0.3, -0.4]);
    }
}
