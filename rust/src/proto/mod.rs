//! Shared message types exchanged between WeiPS roles.
//!
//! All messages hand-implement [`Encode`]/[`Decode`] over the codec
//! primitives. Method ids for RPC dispatch live with the services that
//! register them (`server::service`, `scheduler::service`); this module is
//! only the payload vocabulary.

use crate::codec::{Decode, Encode, Reader, Writer};
use crate::{Error, Result};

/// Feature/parameter identifier (already hashed upstream).
pub type ParamId = u64;
/// Monotonic model version (checkpoint id).
pub type Version = u64;

// ---------------------------------------------------------------------------
// Sparse pull/push
// ---------------------------------------------------------------------------

/// Pull rows for `ids` from a sparse table. `slot` selects which optimizer
/// slot to read: serving pulls only `w`, training pulls all slots.
#[derive(Debug, Clone, PartialEq)]
pub struct SparsePull {
    pub model: String,
    pub table: String,
    pub ids: Vec<ParamId>,
    /// Slot name ("w", "z", ... or "*" for the full row).
    pub slot: String,
}

impl Encode for SparsePull {
    fn encode(&self, w: &mut Writer) {
        w.put_str(&self.model);
        w.put_str(&self.table);
        w.put_str(&self.slot);
        w.put_u64_slice(&self.ids);
    }
}

impl Decode for SparsePull {
    fn decode(r: &mut Reader) -> Result<Self> {
        Ok(SparsePull {
            model: r.get_str()?,
            table: r.get_str()?,
            slot: r.get_str()?,
            ids: r.get_u64_slice()?,
        })
    }
}

/// Response to [`SparsePull`]: `values.len() == ids.len() * width`.
#[derive(Debug, Clone, PartialEq)]
pub struct SparseValues {
    /// Floats per id (slot dim, or full row width for "*").
    pub width: u32,
    pub values: Vec<f32>,
}

impl Encode for SparseValues {
    fn encode(&self, w: &mut Writer) {
        w.put_u32(self.width);
        w.put_f32_slice(&self.values);
    }
}

impl Decode for SparseValues {
    fn decode(r: &mut Reader) -> Result<Self> {
        Ok(SparseValues { width: r.get_u32()?, values: r.get_f32_slice()? })
    }
}

/// Push gradients for `ids` into a sparse table (master applies the
/// optimizer server-side). `grads.len() == ids.len() * dim`.
#[derive(Debug, Clone, PartialEq)]
pub struct SparsePush {
    pub model: String,
    pub table: String,
    pub ids: Vec<ParamId>,
    pub grads: Vec<f32>,
}

impl Encode for SparsePush {
    fn encode(&self, w: &mut Writer) {
        w.put_str(&self.model);
        w.put_str(&self.table);
        w.put_u64_slice(&self.ids);
        w.put_f32_slice(&self.grads);
    }
}

impl Decode for SparsePush {
    fn decode(r: &mut Reader) -> Result<Self> {
        Ok(SparsePush {
            model: r.get_str()?,
            table: r.get_str()?,
            ids: r.get_u64_slice()?,
            grads: r.get_f32_slice()?,
        })
    }
}

// ---------------------------------------------------------------------------
// Dense pull/push
// ---------------------------------------------------------------------------

/// Pull a full dense table (tower weights, bias).
#[derive(Debug, Clone, PartialEq)]
pub struct DensePull {
    pub model: String,
    pub table: String,
}

impl Encode for DensePull {
    fn encode(&self, w: &mut Writer) {
        w.put_str(&self.model);
        w.put_str(&self.table);
    }
}

impl Decode for DensePull {
    fn decode(r: &mut Reader) -> Result<Self> {
        Ok(DensePull { model: r.get_str()?, table: r.get_str()? })
    }
}

/// Dense table content (also the dense push payload).
#[derive(Debug, Clone, PartialEq)]
pub struct DenseValues {
    pub model: String,
    pub table: String,
    pub values: Vec<f32>,
}

impl Encode for DenseValues {
    fn encode(&self, w: &mut Writer) {
        w.put_str(&self.model);
        w.put_str(&self.table);
        w.put_f32_slice(&self.values);
    }
}

impl Decode for DenseValues {
    fn decode(r: &mut Reader) -> Result<Self> {
        Ok(DenseValues {
            model: r.get_str()?,
            table: r.get_str()?,
            values: r.get_f32_slice()?,
        })
    }
}

// ---------------------------------------------------------------------------
// Streaming sync records (the external-queue payload, §4.1)
// ---------------------------------------------------------------------------

/// Operation carried by a sync entry. Per the paper's eventual-consistency
/// rule (§4.1d) an upsert always carries the *full current value* of the id
/// (not a delta), so replay is idempotent; deletes propagate the feature
/// filter (§4.1c).
#[derive(Debug, Clone, PartialEq)]
pub enum SyncOp {
    /// Full row state for the id.
    Upsert(Vec<f32>),
    /// Remove the id (feature-filter eviction).
    Delete,
}

/// One id's update inside a sync batch.
#[derive(Debug, Clone, PartialEq)]
pub struct SyncEntry {
    pub id: ParamId,
    pub op: SyncOp,
}

/// A batch of updates for one (model, table, master-shard), produced by the
/// pusher, consumed by slave scatters. `seq` is the per-shard monotonic
/// batch number used for gap/lag metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct SyncBatch {
    pub model: String,
    pub table: String,
    pub shard: u32,
    pub seq: u64,
    /// Wall-clock of gather time (ms) — measures end-to-end sync latency.
    pub created_ms: u64,
    pub entries: Vec<SyncEntry>,
    /// Dense tables sync as whole-value snapshots (empty for sparse).
    pub dense: Vec<f32>,
}

impl Encode for SyncBatch {
    fn encode(&self, w: &mut Writer) {
        w.put_str(&self.model);
        w.put_str(&self.table);
        w.put_u32(self.shard);
        w.put_u64(self.seq);
        w.put_u64(self.created_ms);
        w.put_varint(self.entries.len() as u64);
        for e in &self.entries {
            w.put_varint(e.id);
            match &e.op {
                SyncOp::Upsert(vals) => {
                    w.put_u8(0);
                    w.put_f32_slice(vals);
                }
                SyncOp::Delete => w.put_u8(1),
            }
        }
        w.put_f32_slice(&self.dense);
    }
}

impl Decode for SyncBatch {
    fn decode(r: &mut Reader) -> Result<Self> {
        let model = r.get_str()?;
        let table = r.get_str()?;
        let shard = r.get_u32()?;
        let seq = r.get_u64()?;
        let created_ms = r.get_u64()?;
        let n = r.get_varint()? as usize;
        if n > r.remaining() + 1 {
            return Err(Error::Codec(format!("sync batch entry count {n} exceeds buffer")));
        }
        let mut entries = Vec::with_capacity(n);
        for _ in 0..n {
            let id = r.get_varint()?;
            let op = match r.get_u8()? {
                0 => SyncOp::Upsert(r.get_f32_slice()?),
                1 => SyncOp::Delete,
                t => return Err(Error::Codec(format!("unknown sync op {t}"))),
            };
            entries.push(SyncEntry { id, op });
        }
        let dense = r.get_f32_slice()?;
        Ok(SyncBatch { model, table, shard, seq, created_ms, entries, dense })
    }
}

// ---------------------------------------------------------------------------
// Control-plane messages
// ---------------------------------------------------------------------------

/// Node heartbeat to the scheduler.
#[derive(Debug, Clone, PartialEq)]
pub struct Heartbeat {
    pub node: String,
    pub role: String,
    pub healthy: bool,
    /// Free-form load metric (QPS, queue depth) for balancing decisions.
    pub load: f64,
}

impl Encode for Heartbeat {
    fn encode(&self, w: &mut Writer) {
        w.put_str(&self.node);
        w.put_str(&self.role);
        w.put_u8(self.healthy as u8);
        w.put_f64(self.load);
    }
}

impl Decode for Heartbeat {
    fn decode(r: &mut Reader) -> Result<Self> {
        Ok(Heartbeat {
            node: r.get_str()?,
            role: r.get_str()?,
            healthy: r.get_u8()? != 0,
            load: r.get_f64()?,
        })
    }
}

/// Checkpoint request from the scheduler to a master shard.
#[derive(Debug, Clone, PartialEq)]
pub struct CkptRequest {
    pub model: String,
    pub version: Version,
    /// Queue offsets captured at trigger time, stored in the checkpoint so
    /// a rollback can resume streaming from the right position (§4.3.2).
    pub queue_offsets: Vec<u64>,
}

impl Encode for CkptRequest {
    fn encode(&self, w: &mut Writer) {
        w.put_str(&self.model);
        w.put_u64(self.version);
        w.put_u64_slice(&self.queue_offsets);
    }
}

impl Decode for CkptRequest {
    fn decode(r: &mut Reader) -> Result<Self> {
        Ok(CkptRequest {
            model: r.get_str()?,
            version: r.get_u64()?,
            queue_offsets: r.get_u64_slice()?,
        })
    }
}

// ---------------------------------------------------------------------------
// Elastic resharding (slot migration + routing-epoch control)
// ---------------------------------------------------------------------------

/// Pull a slot-filtered chunk from a migration donor. `since = 0` is the
/// full base pass; `since = cut + 1` collects rows stamped after `cut`.
/// The response is the raw chunk (`MasterShard::encode_slot_chunk`
/// bytes), fed verbatim to `MIGRATE_APPLY` on the recipient.
#[derive(Debug, Clone, PartialEq)]
pub struct SlotPull {
    pub model: String,
    /// 0 = full base pass, else `cut + 1`.
    pub since: u64,
    /// Slot universe size (must match the cluster's `reshard_slots`).
    pub universe: u32,
    pub slots: Vec<u16>,
}

impl Encode for SlotPull {
    fn encode(&self, w: &mut Writer) {
        w.put_str(&self.model);
        w.put_varint(self.since);
        w.put_u32(self.universe);
        w.put_varint(self.slots.len() as u64);
        for &s in &self.slots {
            w.put_varint(s as u64);
        }
    }
}

/// Read a varint-framed slot list (shared by the reshard messages and
/// the slot-chunk header): count, then one varint per slot, each
/// validated into the u16 slot space.
pub fn read_slot_list(r: &mut Reader) -> Result<Vec<u16>> {
    let n = r.get_varint()? as usize;
    let mut slots = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        let s = r.get_varint()?;
        if s > u16::MAX as u64 {
            return Err(Error::Codec(format!("slot {s} out of range")));
        }
        slots.push(s as u16);
    }
    Ok(slots)
}

impl Decode for SlotPull {
    fn decode(r: &mut Reader) -> Result<Self> {
        Ok(SlotPull {
            model: r.get_str()?,
            since: r.get_varint()?,
            universe: r.get_u32()?,
            slots: read_slot_list(r)?,
        })
    }
}

/// Seal (or, with an empty slot list, unseal) slots on a migration donor
/// for the hand-off window.
#[derive(Debug, Clone, PartialEq)]
pub struct SlotSeal {
    pub model: String,
    pub universe: u32,
    pub slots: Vec<u16>,
}

impl Encode for SlotSeal {
    fn encode(&self, w: &mut Writer) {
        w.put_str(&self.model);
        w.put_u32(self.universe);
        w.put_varint(self.slots.len() as u64);
        for &s in &self.slots {
            w.put_varint(s as u64);
        }
    }
}

impl Decode for SlotSeal {
    fn decode(r: &mut Reader) -> Result<Self> {
        Ok(SlotSeal {
            model: r.get_str()?,
            universe: r.get_u32()?,
            slots: read_slot_list(r)?,
        })
    }
}

/// Generic OK/metadata reply.
#[derive(Debug, Clone, PartialEq)]
pub struct Ack {
    pub ok: bool,
    pub detail: String,
}

impl Ack {
    /// Successful ack.
    pub fn ok() -> Ack {
        Ack { ok: true, detail: String::new() }
    }
}

impl Encode for Ack {
    fn encode(&self, w: &mut Writer) {
        w.put_u8(self.ok as u8);
        w.put_str(&self.detail);
    }
}

impl Decode for Ack {
    fn decode(r: &mut Reader) -> Result<Self> {
        Ok(Ack { ok: r.get_u8()? != 0, detail: r.get_str()? })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Strategy};
    use crate::util::Rng;

    fn round_trip<T: Encode + Decode + PartialEq + std::fmt::Debug>(v: &T) {
        let bytes = v.to_bytes();
        let back = T::from_bytes(&bytes).expect("decode");
        assert_eq!(&back, v);
    }

    #[test]
    fn sparse_messages_round_trip() {
        round_trip(&SparsePull {
            model: "ctr".into(),
            table: "w".into(),
            ids: vec![1, 99, u64::MAX],
            slot: "*".into(),
        });
        round_trip(&SparseValues { width: 8, values: vec![1.0, -2.5, 0.0] });
        round_trip(&SparsePush {
            model: "ctr".into(),
            table: "v".into(),
            ids: vec![5, 6],
            grads: vec![0.25; 16],
        });
    }

    #[test]
    fn reshard_messages_round_trip() {
        round_trip(&SlotPull { model: "ctr".into(), since: 0, universe: 1024, slots: vec![] });
        round_trip(&SlotPull {
            model: "ctr".into(),
            since: 17,
            universe: 64,
            slots: vec![0, 9, 63, u16::MAX],
        });
        round_trip(&SlotSeal { model: "ctr".into(), universe: 64, slots: vec![3, 7] });
        // Truncation errors cleanly.
        let bytes =
            SlotPull { model: "m".into(), since: 1, universe: 8, slots: vec![1, 2, 3] }.to_bytes();
        assert!(SlotPull::from_bytes(&bytes[..bytes.len() - 2]).is_err());
    }

    #[test]
    fn dense_messages_round_trip() {
        round_trip(&DensePull { model: "m".into(), table: "tower.w1".into() });
        round_trip(&DenseValues {
            model: "m".into(),
            table: "tower.w1".into(),
            values: (0..100).map(|i| i as f32).collect(),
        });
    }

    #[test]
    fn sync_batch_round_trips() {
        round_trip(&SyncBatch {
            model: "ctr".into(),
            table: "w".into(),
            shard: 3,
            seq: 42,
            created_ms: 1_700_000_000_000,
            entries: vec![
                SyncEntry { id: 7, op: SyncOp::Upsert(vec![1.0, 2.0, 3.0]) },
                SyncEntry { id: 8, op: SyncOp::Delete },
            ],
            dense: vec![],
        });
        round_trip(&SyncBatch {
            model: "ctr".into(),
            table: "bias".into(),
            shard: 0,
            seq: 0,
            created_ms: 0,
            entries: vec![],
            dense: vec![0.5],
        });
    }

    #[test]
    fn control_messages_round_trip() {
        round_trip(&Heartbeat { node: "m0".into(), role: "master".into(), healthy: true, load: 0.7 });
        round_trip(&CkptRequest { model: "ctr".into(), version: 12, queue_offsets: vec![3, 9, 0] });
        round_trip(&Ack::ok());
        round_trip(&Ack { ok: false, detail: "shard down".into() });
    }

    #[test]
    fn decode_rejects_truncation_and_garbage() {
        let bytes = SparsePush {
            model: "m".into(),
            table: "t".into(),
            ids: vec![1, 2, 3],
            grads: vec![1.0; 6],
        }
        .to_bytes();
        for cut in 0..bytes.len() {
            assert!(SparsePush::from_bytes(&bytes[..cut]).is_err(), "cut={cut}");
        }
        // Unknown sync op tag.
        let mut batch = SyncBatch {
            model: "m".into(),
            table: "t".into(),
            shard: 0,
            seq: 1,
            created_ms: 2,
            entries: vec![SyncEntry { id: 1, op: SyncOp::Delete }],
            dense: vec![],
        }
        .to_bytes();
        // Find and corrupt the op tag (last non-dense byte block); simplest
        // robust approach: flip every byte and require decode not to panic.
        for i in 0..batch.len() {
            batch[i] ^= 0xFF;
            let _ = SyncBatch::from_bytes(&batch); // must not panic
            batch[i] ^= 0xFF;
        }
    }

    #[test]
    fn prop_sync_batch_round_trips() {
        struct BatchStrat;
        impl Strategy for BatchStrat {
            type Value = SyncBatch;
            fn gen(&self, rng: &mut Rng) -> SyncBatch {
                let n = rng.gen_range(20) as usize;
                let entries = (0..n)
                    .map(|_| {
                        let id = rng.next_u64() >> 16;
                        let op = if rng.gen_bool(0.8) {
                            let d = 1 + rng.gen_range(8) as usize;
                            SyncOp::Upsert((0..d).map(|_| rng.gen_f32() - 0.5).collect())
                        } else {
                            SyncOp::Delete
                        };
                        SyncEntry { id, op }
                    })
                    .collect();
                SyncBatch {
                    model: "m".into(),
                    table: if rng.gen_bool(0.5) { "w" } else { "v" }.into(),
                    shard: rng.gen_range(16) as u32,
                    seq: rng.next_u64() >> 32,
                    created_ms: rng.next_u64() >> 20,
                    entries,
                    dense: vec![],
                }
            }
        }
        check("syncbatch-roundtrip", &BatchStrat, 200, |b| {
            let bytes = b.to_bytes();
            let back = SyncBatch::from_bytes(&bytes).map_err(|e| e.to_string())?;
            if &back != b {
                return Err("mismatch".into());
            }
            Ok(())
        });
    }
}
