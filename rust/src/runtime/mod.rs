//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute on the
//! hot path.
//!
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` → `compile` →
//! `execute` (the pattern from /opt/xla-example/load_hlo). One compiled
//! executable per module variant, cached for the process lifetime; Python
//! is never invoked at runtime.

mod manifest;

pub use manifest::{DType, Manifest, ModelConfig, ModuleMeta, TensorMeta};

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use crate::{Error, Result};

/// Host-side f32 tensor (shape + row-major data).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    /// New tensor; panics if shape/product mismatch (programmer error).
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} vs {} elements",
            data.len()
        );
        Tensor { shape, data }
    }

    /// Rank-0 scalar.
    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    /// Rank-1 vector.
    pub fn vec1(v: Vec<f32>) -> Tensor {
        Tensor { shape: vec![v.len()], data: v }
    }

    /// Zero-filled tensor of `shape`.
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// First element (scalars).
    pub fn item(&self) -> f32 {
        self.data[0]
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(self.data.as_ptr() as *const u8, self.data.len() * 4)
        };
        xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            &self.shape,
            bytes,
        )
        .map_err(Into::into)
    }

    fn from_literal(lit: &xla::Literal, meta: &TensorMeta) -> Result<Tensor> {
        let data = lit.to_vec::<f32>()?;
        if data.len() != meta.elements() {
            return Err(Error::Runtime(format!(
                "output has {} elements, manifest says {:?}",
                data.len(),
                meta.shape
            )));
        }
        Ok(Tensor { shape: meta.shape.clone(), data })
    }
}

/// PJRT wrapper types are raw-pointer handles; the underlying PJRT CPU
/// client is thread-safe for compilation and execution, so we assert Send +
/// Sync and serialize executions per-module with a mutex below.
struct SendExec(xla::PjRtLoadedExecutable);
unsafe impl Send for SendExec {}
unsafe impl Sync for SendExec {}

struct SendClient(xla::PjRtClient);
unsafe impl Send for SendClient {}
unsafe impl Sync for SendClient {}

struct CompiledModule {
    meta: ModuleMeta,
    exec: SendExec,
    /// PJRT CPU execute is internally synchronized but not reentrant-safe
    /// for our buffer handling; serialize per module.
    lock: Mutex<()>,
}

/// Loaded artifact set + PJRT client. Cheap to share behind an `Arc`.
pub struct Engine {
    manifest: Manifest,
    client: SendClient,
    modules: Mutex<HashMap<String, &'static CompiledModule>>,
}

impl Engine {
    /// Load the manifest in `dir` and initialize the PJRT CPU client.
    /// Modules compile lazily on first use.
    pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine { manifest, client: SendClient(client), modules: Mutex::new(HashMap::new()) })
    }

    /// The parsed manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Model hyper-parameters from the manifest.
    pub fn config(&self) -> &ModelConfig {
        &self.manifest.config
    }

    /// Compile (or fetch cached) module `name`.
    fn module(&self, name: &str) -> Result<&'static CompiledModule> {
        let mut cache = self.modules.lock().unwrap();
        if let Some(m) = cache.get(name) {
            return Ok(m);
        }
        let meta = self.manifest.module(name)?.clone();
        let path = self.manifest.module_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exec = self.client.0.compile(&comp)?;
        // Executables live for the process lifetime; leak into &'static so
        // callers can hold references without lifetime plumbing.
        let module: &'static CompiledModule = Box::leak(Box::new(CompiledModule {
            meta,
            exec: SendExec(exec),
            lock: Mutex::new(()),
        }));
        cache.insert(name.to_string(), module);
        Ok(module)
    }

    /// Force-compile `name` now (startup warming).
    pub fn warm(&self, name: &str) -> Result<()> {
        self.module(name).map(|_| ())
    }

    /// Execute module `name` on `inputs`; validates shapes against the
    /// manifest and returns outputs in manifest order.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let module = self.module(name)?;
        if inputs.len() != module.meta.inputs.len() {
            return Err(Error::Runtime(format!(
                "{name}: {} inputs given, manifest wants {}",
                inputs.len(),
                module.meta.inputs.len()
            )));
        }
        for (i, (t, m)) in inputs.iter().zip(&module.meta.inputs).enumerate() {
            if t.shape != m.shape {
                return Err(Error::Runtime(format!(
                    "{name}: input {i} shape {:?} != manifest {:?}",
                    t.shape, m.shape
                )));
            }
        }
        let literals = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;

        let result = {
            let _guard = module.lock.lock().unwrap();
            module.exec.0.execute::<xla::Literal>(&literals)?
        };
        let first = result
            .into_iter()
            .next()
            .and_then(|d| d.into_iter().next())
            .ok_or_else(|| Error::Runtime(format!("{name}: no output buffer")))?;
        // aot.py lowers with return_tuple=True: single tuple of k outputs.
        let tuple = first.to_literal_sync()?.to_tuple()?;
        if tuple.len() != module.meta.outputs.len() {
            return Err(Error::Runtime(format!(
                "{name}: {} outputs, manifest says {}",
                tuple.len(),
                module.meta.outputs.len()
            )));
        }
        tuple
            .iter()
            .zip(&module.meta.outputs)
            .map(|(lit, meta)| Tensor::from_literal(lit, meta))
            .collect()
    }
}

/// Locate the artifacts directory for tests/benches: `WEIPS_ARTIFACTS` env
/// var or `<manifest dir>/artifacts`.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("WEIPS_ARTIFACTS") {
        return p.into();
    }
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Option<Engine> {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping runtime test: run `make artifacts` first");
            return None;
        }
        Some(Engine::load(dir).expect("engine load"))
    }

    #[test]
    fn tensor_construction() {
        let t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
        assert_eq!(Tensor::scalar(2.5).item(), 2.5);
        assert_eq!(Tensor::vec1(vec![1.0, 2.0]).shape, vec![2]);
        assert_eq!(Tensor::zeros(&[4, 2]).data, vec![0.0; 8]);
    }

    #[test]
    #[should_panic]
    fn tensor_shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![0.0; 3]);
    }

    #[test]
    fn lr_predict_matches_manual_sigmoid() {
        let Some(eng) = engine() else { return };
        let cfg = eng.config().clone();
        let b = cfg.batch_predict;
        let f = cfg.fields;
        // w[i][j] = 0.01*(i+1), bias = 0.5
        let mut w = Vec::with_capacity(b * f);
        for i in 0..b {
            for _ in 0..f {
                w.push(0.01 * (i + 1) as f32);
            }
        }
        let out = eng
            .execute(
                "lr_predict",
                &[Tensor::new(vec![b, f], w), Tensor::vec1(vec![0.5])],
            )
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape, vec![b]);
        for i in 0..b {
            let logit = 0.01 * (i + 1) as f32 * f as f32 + 0.5;
            let want = 1.0 / (1.0 + (-logit).exp());
            assert!(
                (out[0].data[i] - want).abs() < 1e-5,
                "row {i}: {} vs {want}",
                out[0].data[i]
            );
        }
    }

    #[test]
    fn lr_train_loss_and_grads_consistent() {
        let Some(eng) = engine() else { return };
        let cfg = eng.config().clone();
        let (b, f) = (cfg.batch_train, cfg.fields);
        let w = Tensor::zeros(&[b, f]);
        let bias = Tensor::vec1(vec![0.0]);
        let label = Tensor::vec1((0..b).map(|i| (i % 2) as f32).collect());
        let out = eng.execute("lr_train", &[w, bias, label.clone()]).unwrap();
        assert_eq!(out.len(), 4);
        // Zero weights => p = 0.5 for all rows; loss = ln 2.
        for p in &out[0].data {
            assert!((p - 0.5).abs() < 1e-6);
        }
        assert!((out[1].item() - std::f32::consts::LN_2).abs() < 1e-5);
        // grad w.r.t. w row i = (p - y)/B = (0.5 - y)/B for every field.
        for i in 0..b {
            let want = (0.5 - label.data[i]) / b as f32;
            for j in 0..f {
                let g = out[2].data[i * f + j];
                assert!((g - want).abs() < 1e-6, "g[{i}][{j}]={g} want {want}");
            }
        }
    }

    #[test]
    fn execute_rejects_wrong_shapes() {
        let Some(eng) = engine() else { return };
        let err = eng
            .execute("lr_predict", &[Tensor::zeros(&[1, 1]), Tensor::vec1(vec![0.0])])
            .unwrap_err();
        assert!(err.to_string().contains("shape"), "{err}");
        assert!(eng.execute("lr_predict", &[Tensor::scalar(0.0)]).is_err());
        assert!(eng.execute("no_such_module", &[]).is_err());
    }

    #[test]
    fn ftrl_update_module_runs() {
        let Some(eng) = engine() else { return };
        let rows = eng.config().ftrl_block_rows;
        let g = Tensor::new(vec![rows, 1], vec![1.0; rows]);
        let z = Tensor::zeros(&[rows, 1]);
        let n = Tensor::zeros(&[rows, 1]);
        let out = eng.execute("ftrl_update_d1", &[g, z, n]).unwrap();
        assert_eq!(out.len(), 3);
        // n' = g^2 = 1, z' = g - sigma*w_old = 1 (w_old = 0).
        assert!((out[1].data[0] - 1.0).abs() < 1e-6);
        assert!((out[0].data[0] - 1.0).abs() < 1e-6);
        // |z'| = 1 > l1 => w' = -(z'-l1)/((beta+sqrt(n'))/alpha + l2) < 0.
        let cfg = eng.config();
        let expect = -(1.0 - cfg.ftrl_l1)
            / ((cfg.ftrl_beta + 1.0f32.sqrt()) / cfg.ftrl_alpha + cfg.ftrl_l2);
        assert!((out[2].data[0] - expect).abs() < 1e-6, "w'={} want {expect}", out[2].data[0]);
    }

    #[test]
    fn concurrent_execution_is_safe() {
        let Some(eng) = engine() else { return };
        let eng = std::sync::Arc::new(eng);
        let cfg = eng.config().clone();
        let (b, f) = (cfg.batch_predict, cfg.fields);
        let mut handles = Vec::new();
        for t in 0..4 {
            let eng = eng.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..10 {
                    let w = Tensor::new(vec![b, f], vec![0.1 * t as f32; b * f]);
                    let out = eng
                        .execute("lr_predict", &[w, Tensor::vec1(vec![0.0])])
                        .unwrap();
                    assert_eq!(out[0].shape, vec![b]);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }
}
