//! AOT artifact manifest (written by `python/compile/aot.py`).
//!
//! The manifest pins the contract between build-time Python and the Rust
//! hot path: module names, input/output tensor shapes and dtypes, and the
//! model hyper-parameters (batch sizes, field count, factor dim, FTRL
//! hypers) both sides must agree on.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;
use crate::{Error, Result};

/// Tensor dtype in the manifest (everything WeiPS ships today is f32).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    S32,
    U32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "s32" => Ok(DType::S32),
            "u32" => Ok(DType::U32),
            other => Err(Error::Config(format!("unknown dtype {other}"))),
        }
    }

    /// Bytes per element.
    pub fn size(&self) -> usize {
        4
    }
}

/// Shape + dtype of one module input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorMeta {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorMeta {
    /// Total element count.
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-lowered module.
#[derive(Debug, Clone)]
pub struct ModuleMeta {
    pub name: String,
    /// HLO text file, relative to the artifacts dir.
    pub path: PathBuf,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
}

/// Model/optimizer hyper-parameters shared across layers.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub batch_train: usize,
    pub batch_predict: usize,
    pub fields: usize,
    pub dim: usize,
    pub hidden: usize,
    pub ftrl_block_rows: usize,
    pub ftrl_alpha: f32,
    pub ftrl_beta: f32,
    pub ftrl_l1: f32,
    pub ftrl_l2: f32,
}

/// Parsed artifacts manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: ModelConfig,
    pub modules: BTreeMap<String, ModuleMeta>,
}

fn tensor_meta(j: &Json) -> Result<TensorMeta> {
    let shape = j
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::Config("tensor missing shape".into()))?
        .iter()
        .map(|v| v.as_i64().map(|x| x as usize))
        .collect::<Option<Vec<_>>>()
        .ok_or_else(|| Error::Config("non-integer dim".into()))?;
    let dtype = DType::parse(
        j.get("dtype")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Config("tensor missing dtype".into()))?,
    )?;
    Ok(TensorMeta { shape, dtype })
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    j.get(key)
        .and_then(Json::as_i64)
        .map(|v| v as usize)
        .ok_or_else(|| Error::Config(format!("manifest config missing {key}")))
}

fn req_f32(j: &Json, key: &str) -> Result<f32> {
    j.get(key)
        .and_then(Json::as_f64)
        .map(|v| v as f32)
        .ok_or_else(|| Error::Config(format!("manifest ftrl missing {key}")))
}

impl Manifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            Error::Config(format!(
                "cannot read {}/manifest.json (run `make artifacts`): {e}",
                dir.display()
            ))
        })?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let version = j.get("version").and_then(Json::as_i64).unwrap_or(0);
        if version != 1 {
            return Err(Error::Config(format!("unsupported manifest version {version}")));
        }
        let cfg = j
            .get("config")
            .ok_or_else(|| Error::Config("manifest missing config".into()))?;
        let ftrl = cfg
            .get("ftrl")
            .ok_or_else(|| Error::Config("manifest missing ftrl config".into()))?;
        let config = ModelConfig {
            batch_train: req_usize(cfg, "batch_train")?,
            batch_predict: req_usize(cfg, "batch_predict")?,
            fields: req_usize(cfg, "fields")?,
            dim: req_usize(cfg, "dim")?,
            hidden: req_usize(cfg, "hidden")?,
            ftrl_block_rows: req_usize(cfg, "ftrl_block_rows")?,
            ftrl_alpha: req_f32(ftrl, "alpha")?,
            ftrl_beta: req_f32(ftrl, "beta")?,
            ftrl_l1: req_f32(ftrl, "l1")?,
            ftrl_l2: req_f32(ftrl, "l2")?,
        };
        let mods = j
            .get("modules")
            .and_then(Json::as_obj)
            .ok_or_else(|| Error::Config("manifest missing modules".into()))?;
        let mut modules = BTreeMap::new();
        for (name, m) in mods {
            let path = m
                .get("path")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::Config(format!("module {name} missing path")))?;
            let inputs = m
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| Error::Config(format!("module {name} missing inputs")))?
                .iter()
                .map(tensor_meta)
                .collect::<Result<Vec<_>>>()?;
            let outputs = m
                .get("outputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| Error::Config(format!("module {name} missing outputs")))?
                .iter()
                .map(tensor_meta)
                .collect::<Result<Vec<_>>>()?;
            modules.insert(
                name.clone(),
                ModuleMeta { name: name.clone(), path: PathBuf::from(path), inputs, outputs },
            );
        }
        Ok(Manifest { dir, config, modules })
    }

    /// Metadata for module `name`.
    pub fn module(&self, name: &str) -> Result<&ModuleMeta> {
        self.modules
            .get(name)
            .ok_or_else(|| Error::NotFound(format!("module {name} not in manifest")))
    }

    /// Absolute path of a module's HLO text.
    pub fn module_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.module(name)?.path))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "config": {"batch_train": 8, "batch_predict": 2, "fields": 4, "dim": 2,
                 "hidden": 8, "ftrl_block_rows": 64,
                 "ftrl": {"alpha": 0.05, "beta": 1.0, "l1": 1.0, "l2": 1.0}},
      "modules": {
        "lr_train": {"path": "lr_train.hlo.txt",
          "inputs": [{"shape": [8, 4], "dtype": "f32"},
                     {"shape": [1], "dtype": "f32"},
                     {"shape": [8], "dtype": "f32"}],
          "outputs": [{"shape": [8], "dtype": "f32"},
                      {"shape": [], "dtype": "f32"},
                      {"shape": [8, 4], "dtype": "f32"},
                      {"shape": [1], "dtype": "f32"}]}
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/x")).unwrap();
        assert_eq!(m.config.batch_train, 8);
        assert_eq!(m.config.ftrl_alpha, 0.05);
        let lr = m.module("lr_train").unwrap();
        assert_eq!(lr.inputs.len(), 3);
        assert_eq!(lr.inputs[0].shape, vec![8, 4]);
        assert_eq!(lr.outputs[1].shape, Vec::<usize>::new());
        assert_eq!(lr.outputs[1].elements(), 1);
        assert_eq!(
            m.module_path("lr_train").unwrap(),
            PathBuf::from("/tmp/x/lr_train.hlo.txt")
        );
    }

    #[test]
    fn missing_module_is_not_found() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/x")).unwrap();
        assert!(matches!(m.module("nope"), Err(Error::NotFound(_))));
    }

    #[test]
    fn rejects_bad_version_and_missing_fields() {
        assert!(Manifest::parse(r#"{"version": 2}"#, PathBuf::new()).is_err());
        assert!(Manifest::parse(r#"{"version": 1}"#, PathBuf::new()).is_err());
        let no_ftrl = SAMPLE.replace("\"ftrl\"", "\"ftrlX\"");
        assert!(Manifest::parse(&no_ftrl, PathBuf::new()).is_err());
    }

    #[test]
    fn rejects_unknown_dtype() {
        let bad = SAMPLE.replace("\"f32\"", "\"f16\"");
        assert!(Manifest::parse(&bad, PathBuf::new()).is_err());
    }
}
