//! Server-side optimizers (§1.2.1, §4.1.2).
//!
//! In the PS architecture the *server* applies optimizer updates: trainers
//! push raw gradients, the master shard owns the auxiliary state. Each
//! optimizer declares its slot layout — exactly the heterogeneous-
//! parameters problem the paper fuses away: LR-FTRL rows are 3 sparse
//! slots (z, n, w), FM-FTRL 6 (z, n, w per table), serving needs only `w`.
//!
//! Two FTRL implementations exist and are tested against each other:
//! the scalar Rust path here (used per-row on small pushes) and the AOT
//! Pallas kernel (`artifacts/ftrl_update_d*.hlo.txt`, used for large
//! batched blocks via [`BatchedFtrl`]). The math follows
//! `python/compile/kernels/ref.py` bit-for-bit in structure.

use std::sync::Arc;

use crate::runtime::{Engine, Tensor};
use crate::{Error, Result};

/// A server-side optimizer over fixed-width sparse rows.
///
/// A row is `slots().len() * dim` contiguous f32s, slot-major:
/// `[slot0[0..dim], slot1[0..dim], ...]`. The serving weight lives in the
/// slot named `"w"`.
pub trait Optimizer: Send + Sync {
    /// Optimizer name (matches config strings).
    fn name(&self) -> &'static str;

    /// Slot layout, e.g. `["z", "n", "w"]` for FTRL.
    fn slots(&self) -> &'static [&'static str];

    /// Apply one gradient to one row. `step` is the row's update count
    /// (1-based on first call) for bias-corrected optimizers.
    fn apply(&self, row: &mut [f32], grad: &[f32], dim: usize, step: u32);

    /// Floats per row for a given dim.
    fn row_width(&self, dim: usize) -> usize {
        self.slots().len() * dim
    }

    /// Index of a slot by name.
    fn slot_index(&self, name: &str) -> Option<usize> {
        self.slots().iter().position(|s| *s == name)
    }

    /// The serving-weight sub-slice of a row.
    fn serving<'r>(&self, row: &'r [f32], dim: usize) -> &'r [f32] {
        let w = self.slot_index("w").expect("optimizer has no w slot");
        &row[w * dim..(w + 1) * dim]
    }
}

/// Construct an optimizer by config name.
pub fn by_name(name: &str, hp: &FtrlHyper) -> Result<Arc<dyn Optimizer>> {
    match name {
        "ftrl" => Ok(Arc::new(Ftrl::new(hp.clone()))),
        "sgd" => Ok(Arc::new(Sgd { lr: 0.05 })),
        "adagrad" => Ok(Arc::new(Adagrad { lr: 0.05, eps: 1e-8 })),
        "adam" => Ok(Arc::new(Adam { lr: 0.001, b1: 0.9, b2: 0.999, eps: 1e-8 })),
        other => Err(Error::Config(format!("unknown optimizer {other}"))),
    }
}

/// FTRL hyper-parameters (mirrors `aot.FTRL_HYPERS`).
#[derive(Debug, Clone)]
pub struct FtrlHyper {
    pub alpha: f32,
    pub beta: f32,
    pub l1: f32,
    pub l2: f32,
}

impl Default for FtrlHyper {
    fn default() -> Self {
        FtrlHyper { alpha: 0.05, beta: 1.0, l1: 1.0, l2: 1.0 }
    }
}

/// FTRL-proximal (McMahan 2011). Slots: z, n, w (w cached for serving).
pub struct Ftrl {
    hp: FtrlHyper,
}

impl Ftrl {
    /// New FTRL with `hp`.
    pub fn new(hp: FtrlHyper) -> Ftrl {
        Ftrl { hp }
    }

    #[inline]
    fn weight(&self, z: f32, n: f32) -> f32 {
        if z.abs() <= self.hp.l1 {
            0.0
        } else {
            -(z - z.signum() * self.hp.l1)
                / ((self.hp.beta + n.sqrt()) / self.hp.alpha + self.hp.l2)
        }
    }
}

impl Optimizer for Ftrl {
    fn name(&self) -> &'static str {
        "ftrl"
    }

    fn slots(&self) -> &'static [&'static str] {
        &["z", "n", "w"]
    }

    fn apply(&self, row: &mut [f32], grad: &[f32], dim: usize, _step: u32) {
        debug_assert_eq!(row.len(), 3 * dim);
        debug_assert_eq!(grad.len(), dim);
        let (z_slot, rest) = row.split_at_mut(dim);
        let (n_slot, w_slot) = rest.split_at_mut(dim);
        for j in 0..dim {
            let g = grad[j];
            let z = z_slot[j];
            let n = n_slot[j];
            let w_old = self.weight(z, n);
            let n_new = n + g * g;
            let sigma = (n_new.sqrt() - n.sqrt()) / self.hp.alpha;
            let z_new = z + g - sigma * w_old;
            z_slot[j] = z_new;
            n_slot[j] = n_new;
            w_slot[j] = self.weight(z_new, n_new);
        }
    }
}

/// Plain SGD. Slots: w.
pub struct Sgd {
    pub lr: f32,
}

impl Optimizer for Sgd {
    fn name(&self) -> &'static str {
        "sgd"
    }

    fn slots(&self) -> &'static [&'static str] {
        &["w"]
    }

    fn apply(&self, row: &mut [f32], grad: &[f32], dim: usize, _step: u32) {
        debug_assert_eq!(row.len(), dim);
        for j in 0..dim {
            row[j] -= self.lr * grad[j];
        }
    }
}

/// Adagrad. Slots: acc, w.
pub struct Adagrad {
    pub lr: f32,
    pub eps: f32,
}

impl Optimizer for Adagrad {
    fn name(&self) -> &'static str {
        "adagrad"
    }

    fn slots(&self) -> &'static [&'static str] {
        &["acc", "w"]
    }

    fn apply(&self, row: &mut [f32], grad: &[f32], dim: usize, _step: u32) {
        let (acc, w) = row.split_at_mut(dim);
        for j in 0..dim {
            let g = grad[j];
            acc[j] += g * g;
            w[j] -= self.lr * g / (acc[j].sqrt() + self.eps);
        }
    }
}

/// Adam with per-row step-based bias correction. Slots: m, v, w.
pub struct Adam {
    pub lr: f32,
    pub b1: f32,
    pub b2: f32,
    pub eps: f32,
}

impl Optimizer for Adam {
    fn name(&self) -> &'static str {
        "adam"
    }

    fn slots(&self) -> &'static [&'static str] {
        &["m", "v", "w"]
    }

    fn apply(&self, row: &mut [f32], grad: &[f32], dim: usize, step: u32) {
        let t = step.max(1) as f32;
        let bc1 = 1.0 - self.b1.powf(t);
        let bc2 = 1.0 - self.b2.powf(t);
        let (m, rest) = row.split_at_mut(dim);
        let (v, w) = rest.split_at_mut(dim);
        for j in 0..dim {
            let g = grad[j];
            m[j] = self.b1 * m[j] + (1.0 - self.b1) * g;
            v[j] = self.b2 * v[j] + (1.0 - self.b2) * g * g;
            let m_hat = m[j] / bc1;
            let v_hat = v[j] / bc2;
            w[j] -= self.lr * m_hat / (v_hat.sqrt() + self.eps);
        }
    }
}

// ---------------------------------------------------------------------------
// Batched FTRL through the AOT Pallas kernel
// ---------------------------------------------------------------------------

/// Applies FTRL to large blocks of rows by executing the AOT Pallas kernel
/// (`ftrl_update_d{dim}`) through PJRT. The master's push hot path batches
/// dirty rows into `(block_rows, dim)` tensors, pads the tail, and scatters
/// the updated (z, n, w) back.
pub struct BatchedFtrl {
    engine: Arc<Engine>,
    dim: usize,
    module: String,
    block_rows: usize,
}

impl BatchedFtrl {
    /// Kernel wrapper for rows of `dim` (requires `ftrl_update_d{dim}` in
    /// the manifest).
    pub fn new(engine: Arc<Engine>, dim: usize) -> Result<BatchedFtrl> {
        let module = format!("ftrl_update_d{dim}");
        engine.manifest().module(&module)?;
        let block_rows = engine.config().ftrl_block_rows;
        Ok(BatchedFtrl { engine, dim, module, block_rows })
    }

    /// Rows per kernel invocation.
    pub fn block_rows(&self) -> usize {
        self.block_rows
    }

    /// Update `k = ids` rows: `g`, `z`, `n` are `k*dim` flat slices;
    /// outputs overwrite `z`, `n` and fill `w`. Handles `k` larger or
    /// smaller than the kernel block by chunking / zero-padding.
    pub fn update(&self, g: &[f32], z: &mut [f32], n: &mut [f32], w: &mut [f32]) -> Result<()> {
        let dim = self.dim;
        let k = g.len() / dim;
        debug_assert_eq!(g.len(), k * dim);
        debug_assert_eq!(z.len(), k * dim);
        let rows = self.block_rows;
        let mut start = 0usize;
        while start < k {
            let take = (k - start).min(rows);
            let lo = start * dim;
            let hi = (start + take) * dim;
            let pad_len = rows * dim;
            let mut gt = vec![0.0f32; pad_len];
            let mut zt = vec![0.0f32; pad_len];
            let mut nt = vec![0.0f32; pad_len];
            gt[..hi - lo].copy_from_slice(&g[lo..hi]);
            zt[..hi - lo].copy_from_slice(&z[lo..hi]);
            nt[..hi - lo].copy_from_slice(&n[lo..hi]);
            let out = self.engine.execute(
                &self.module,
                &[
                    Tensor::new(vec![rows, dim], gt),
                    Tensor::new(vec![rows, dim], zt),
                    Tensor::new(vec![rows, dim], nt),
                ],
            )?;
            z[lo..hi].copy_from_slice(&out[0].data[..hi - lo]);
            n[lo..hi].copy_from_slice(&out[1].data[..hi - lo]);
            w[lo..hi].copy_from_slice(&out[2].data[..hi - lo]);
            start += take;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ftrl() -> Ftrl {
        Ftrl::new(FtrlHyper::default())
    }

    #[test]
    fn ftrl_zero_grad_is_noop() {
        let f = ftrl();
        let mut row = vec![0.5, -0.5, 2.0, 3.0, 0.1, -0.2]; // z, n, w at dim=2
        let before = row.clone();
        f.apply(&mut row, &[0.0, 0.0], 2, 1);
        assert_eq!(&row[..4], &before[..4]); // z, n unchanged
    }

    #[test]
    fn ftrl_l1_dead_zone() {
        let f = ftrl();
        let mut row = vec![0.0; 3];
        f.apply(&mut row, &[1e-4], 1, 1);
        assert_eq!(f.serving(&row, 1)[0], 0.0);
    }

    #[test]
    fn ftrl_repeated_grads_move_weight_negative() {
        let f = ftrl();
        let mut row = vec![0.0; 3];
        for step in 1..=60 {
            f.apply(&mut row, &[1.0], 1, step);
        }
        assert!(f.serving(&row, 1)[0] < 0.0, "w = {}", row[2]);
        // n accumulates g^2.
        assert!((row[1] - 60.0).abs() < 1e-4);
    }

    #[test]
    fn ftrl_matches_python_reference_values() {
        // Golden values from python/compile/kernels/ref.py:
        //   ftrl_update_ref([[0.7]], [[2.0]], [[1.5]])
        //   -> z'=2.7817361, n'=1.99, w'=-0.03620424
        let f = ftrl();
        let mut row = vec![2.0, 1.5, 0.0];
        f.apply(&mut row, &[0.7], 1, 1);
        assert!((row[0] - 2.781_736_1).abs() < 1e-5, "z={}", row[0]);
        assert!((row[1] - 1.99).abs() < 1e-5, "n={}", row[1]);
        assert!((row[2] - (-0.036_204_24)).abs() < 1e-6, "w={}", row[2]);
    }

    #[test]
    fn sgd_moves_against_gradient() {
        let s = Sgd { lr: 0.1 };
        let mut row = vec![1.0, -1.0];
        s.apply(&mut row, &[0.5, -0.5], 2, 1);
        assert_eq!(row, vec![0.95, -0.95]);
    }

    #[test]
    fn adagrad_decays_effective_lr() {
        let a = Adagrad { lr: 0.1, eps: 1e-8 };
        let mut row = vec![0.0, 0.0]; // acc, w at dim=1
        a.apply(&mut row, &[1.0], 1, 1);
        let step1 = -row[1];
        let w1 = row[1];
        a.apply(&mut row, &[1.0], 1, 2);
        let step2 = w1 - row[1];
        assert!(step2 < step1, "step sizes: {step1} then {step2}");
        assert!((row[0] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn adam_bias_correction_first_step() {
        let a = Adam { lr: 0.001, b1: 0.9, b2: 0.999, eps: 1e-8 };
        let mut row = vec![0.0; 3];
        a.apply(&mut row, &[0.3], 1, 1);
        // First step with bias correction ~= -lr * sign(g).
        assert!((row[2] + 0.001).abs() < 1e-4, "w={}", row[2]);
    }

    #[test]
    fn by_name_resolves_and_rejects() {
        let hp = FtrlHyper::default();
        for n in ["ftrl", "sgd", "adagrad", "adam"] {
            assert_eq!(by_name(n, &hp).unwrap().name(), n);
        }
        assert!(by_name("lbfgs", &hp).is_err());
    }

    #[test]
    fn slot_layout_accessors() {
        let f = ftrl();
        assert_eq!(f.row_width(8), 24);
        assert_eq!(f.slot_index("n"), Some(1));
        assert_eq!(f.slot_index("q"), None);
        let row: Vec<f32> = (0..24).map(|i| i as f32).collect();
        assert_eq!(f.serving(&row, 8), &row[16..24]);
    }

    // -- cross-layer: scalar Rust FTRL vs AOT Pallas kernel -------------------

    #[test]
    fn batched_ftrl_matches_scalar() {
        let dir = crate::runtime::default_artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let engine = Arc::new(Engine::load(dir).unwrap());
        let cfg = engine.config().clone();
        let dim = cfg.dim;
        let batched = BatchedFtrl::new(engine, dim).unwrap();
        // Scalar comparator must use the manifest's hypers (the kernel's).
        let scalar = Ftrl::new(FtrlHyper {
            alpha: cfg.ftrl_alpha,
            beta: cfg.ftrl_beta,
            l1: cfg.ftrl_l1,
            l2: cfg.ftrl_l2,
        });

        let k = batched.block_rows() + 137; // force chunk + pad path
        let mut rng = crate::util::Rng::new(42);
        let g: Vec<f32> = (0..k * dim).map(|_| rng.gen_f32() * 2.0 - 1.0).collect();
        let mut z: Vec<f32> = (0..k * dim).map(|_| rng.gen_f32() * 4.0 - 2.0).collect();
        let mut n: Vec<f32> = (0..k * dim).map(|_| rng.gen_f32() * 5.0).collect();
        let mut w = vec![0.0f32; k * dim];

        // Scalar expectation.
        let mut rows_expect = Vec::with_capacity(k);
        for i in 0..k {
            let mut row = vec![0.0f32; 3 * dim];
            row[..dim].copy_from_slice(&z[i * dim..(i + 1) * dim]);
            row[dim..2 * dim].copy_from_slice(&n[i * dim..(i + 1) * dim]);
            scalar.apply(&mut row, &g[i * dim..(i + 1) * dim], dim, 1);
            rows_expect.push(row);
        }

        batched.update(&g, &mut z, &mut n, &mut w).unwrap();
        for i in 0..k {
            for j in 0..dim {
                let (ze, ne, we) =
                    (rows_expect[i][j], rows_expect[i][dim + j], rows_expect[i][2 * dim + j]);
                assert!((z[i * dim + j] - ze).abs() < 1e-4, "z[{i},{j}]");
                assert!((n[i * dim + j] - ne).abs() < 1e-4, "n[{i},{j}]");
                assert!((w[i * dim + j] - we).abs() < 1e-4, "w[{i},{j}]");
            }
        }
    }
}
