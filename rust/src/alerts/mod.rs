//! Cluster health engine: declared alert rules, a pending→firing→resolved
//! evaluator, and a structured event journal (WeiPS §4.3 — the decision
//! layer of "multi-level fault tolerance and real-time domino
//! degradation" made observable).
//!
//! Mirrors the registry discipline of [`crate::metrics`] and
//! [`crate::trace`]:
//!
//! * **Declared rules.** Every alert this build can raise is declared up
//!   front in [`RULES`] — name, severity, query over existing metric
//!   families or registered [`SOURCES`], default bound, and a
//!   `for`-duration (in evaluator ticks) of hysteresis. `docs/METRICS.md`
//!   documents exactly this table (a doc-diff test enforces it).
//! * **Declared sources.** Gauge-shaped inputs that rules and the
//!   `/healthz` readiness probes share ([`SOURCES`]): registering an
//!   undeclared source panics, and the PR 9 `HEALTH_PROBES` bounds now
//!   live here — [`crate::metrics::set_health_bound`] delegates to
//!   [`set_source_bound`], so readiness and alerting can never drift.
//! * **Declared event kinds.** The journal ([`journal`]) only accepts
//!   kinds from [`KINDS`]; every rule-state transition, degradation
//!   engagement (poll-mode fallback, QoS sheds, cache clears, domino
//!   downgrades) and checkpoint/reshard/recovery lifecycle event lands
//!   in a lock-striped ring, optionally persisted to a WAL-style
//!   append-only file ([`set_journal_dir`]), with trace-id correlation
//!   where a sampled batch is implicated.
//!
//! The evaluator ([`evaluate`]) runs on every role — a [`Ticker`] thread
//! on remote roles, the coordinator's control tick locally — and only
//! *reads* registry state, so sync-batch wire bytes are identical with
//! the evaluator on or off (`tests/it_alerts.rs` asserts this;
//! `bench_alerts` gates its cost at ≤1% of pipeline throughput).

use std::collections::{BTreeMap, VecDeque};
use std::fs::{File, OpenOptions};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;

use crate::metrics::{self, SampleFn};
use crate::util::json::Json;
use crate::util::{mono_ns, now_ms};

/// Alert severity, ordered least to most urgent.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Informational — no operator action expected.
    Info,
    /// Needs attention soon; the system is still serving correctly.
    Warning,
    /// Quality or availability is actively degraded.
    Critical,
}

impl Severity {
    /// Lower-case label used in series labels, JSON, and docs.
    pub fn as_str(self) -> &'static str {
        match self {
            Severity::Info => "info",
            Severity::Warning => "warning",
            Severity::Critical => "critical",
        }
    }
}

/// What a rule measures each evaluator tick.
#[derive(Debug, Clone, Copy)]
pub enum Query {
    /// Max across live registered [`SOURCES`] values; breaches when
    /// `value > bound`.
    SourceAbove(&'static str),
    /// Min across live registered [`SOURCES`] values; breaches when
    /// `value < bound`.
    SourceBelow(&'static str),
    /// Per-second increase of a counter family (summed over series);
    /// breaches when `rate > bound`. The first evaluation only arms the
    /// baseline and never breaches.
    RateAbove(&'static str),
    /// p99 of a histogram family (merged over series, in seconds);
    /// breaches when `p99 > bound`.
    P99Above(&'static str),
}

/// Compile-time declaration of one alert rule.
#[derive(Debug)]
pub struct Rule {
    /// Stable rule name (`snake_case`; the `rule` label of
    /// `weips_alert_state` and the journal event name).
    pub name: &'static str,
    /// Severity exported as the `severity` label.
    pub severity: Severity,
    /// What the rule measures.
    pub query: Query,
    /// Default bound; [`set_rule_bound`] / [`set_source_bound`] override
    /// it at runtime (the `health_*` and trigger knobs flow in here).
    pub bound: f64,
    /// Hysteresis: consecutive breaching evaluations spent *pending*
    /// before the rule fires (0 = fire on the first breach).
    pub for_ticks: u64,
    /// One-line operator help (doc-diff-tested into `docs/METRICS.md`).
    pub help: &'static str,
}

/// Every alert rule this build can evaluate, in exposition order.
/// `docs/METRICS.md` documents exactly this list (a test enforces it).
pub static RULES: &[Rule] = &[
    Rule {
        name: "push_visible_p99_high",
        severity: Severity::Warning,
        query: Query::P99Above("weips_push_visible_latency_seconds"),
        bound: 0.5,
        for_ticks: 3,
        help: "p99 push-to-visible sync latency above bound (seconds).",
    },
    Rule {
        name: "scatter_lag_high",
        severity: Severity::Warning,
        query: Query::SourceAbove("scatter_lag_records"),
        bound: 1_000_000.0,
        for_ticks: 2,
        help: "A scatter consumer is falling behind the sync queue (records).",
    },
    Rule {
        name: "wal_unsynced_high",
        severity: Severity::Warning,
        query: Query::SourceAbove("wal_unsynced_appends"),
        bound: 1_000_000.0,
        for_ticks: 2,
        help: "WAL appends since the last fsync exceed the durability bound.",
    },
    Rule {
        name: "qos_shed_rate_high",
        severity: Severity::Warning,
        query: Query::RateAbove("weips_rpc_class_shed_total"),
        bound: 100.0,
        for_ticks: 2,
        help: "QoS admission is shedding requests faster than bound per second.",
    },
    Rule {
        name: "window_auc_low",
        severity: Severity::Critical,
        query: Query::SourceBelow("model_window_auc"),
        bound: 0.55,
        for_ticks: 0,
        help: "Sliding-window AUC collapsed below the domino trigger threshold.",
    },
];

/// Every gauge-shaped input rules (and the `/healthz` readiness probes)
/// can read: (name, display text). Like [`RULES`], registering an
/// undeclared source panics.
pub static SOURCES: &[(&str, &str)] = &[
    ("scatter_lag_records", "scatter lag"),
    ("wal_unsynced_appends", "WAL unsynced appends"),
    ("model_window_auc", "window AUC"),
];

/// Every event kind the journal accepts. Undeclared kinds panic — the
/// journal's vocabulary is designed, not ad hoc.
pub static KINDS: &[&str] = &[
    "alert_pending",
    "alert_firing",
    "alert_resolved",
    "degradation",
    "checkpoint",
    "reshard",
    "recovery",
];

fn kind_index(kind: &str) -> usize {
    KINDS
        .iter()
        .position(|k| *k == kind)
        .unwrap_or_else(|| panic!("alerts: event kind {kind} is not declared in KINDS"))
}

fn source_what(name: &str) -> &'static str {
    SOURCES
        .iter()
        .find(|(n, _)| *n == name)
        .map(|(_, what)| *what)
        .unwrap_or_else(|| panic!("alerts: source {name} is not declared in SOURCES"))
}

fn rule_by_name(name: &str) -> &'static Rule {
    RULES
        .iter()
        .find(|r| r.name == name)
        .unwrap_or_else(|| panic!("alerts: rule {name} is not declared in RULES"))
}

// ---------------------------------------------------------------------------
// Sources and bounds (shared with /healthz readiness)
// ---------------------------------------------------------------------------

struct SourceState {
    sources: BTreeMap<&'static str, Vec<(String, SampleFn)>>,
    /// Explicit per-source bounds (the `health_*` knobs land here).
    source_bounds: BTreeMap<&'static str, f64>,
    /// Explicit per-rule bound overrides (e.g. the domino trigger
    /// threshold for `window_auc_low`).
    rule_bounds: BTreeMap<&'static str, f64>,
}

fn sources() -> &'static Mutex<SourceState> {
    static S: OnceLock<Mutex<SourceState>> = OnceLock::new();
    S.get_or_init(|| {
        Mutex::new(SourceState {
            sources: BTreeMap::new(),
            source_bounds: BTreeMap::new(),
            rule_bounds: BTreeMap::new(),
        })
    })
}

/// Register (or replace, keyed by `detail`) a sampled input source. The
/// closure follows the [`SampleFn`] contract — `None` once the owner is
/// dropped prunes the entry. Panics if `name` is not declared in
/// [`SOURCES`].
pub fn register_source(name: &'static str, detail: String, f: SampleFn) {
    source_what(name);
    let mut s = sources().lock().unwrap();
    let entries = s.sources.entry(name).or_default();
    entries.retain(|(d, _)| *d != detail);
    entries.push((detail, f));
}

/// Set (or clear) the explicit bound for a declared source. `None` or a
/// non-positive bound clears it: readiness then stops checking the
/// probe, and rules fall back to their declared default bound.
pub fn set_source_bound(name: &'static str, bound: Option<f64>) {
    source_what(name);
    let mut s = sources().lock().unwrap();
    match bound.filter(|b| *b > 0.0) {
        Some(b) => {
            s.source_bounds.insert(name, b);
        }
        None => {
            s.source_bounds.remove(name);
        }
    }
}

/// Override (or clear, with `None`) one rule's bound — e.g. the
/// coordinator pins `window_auc_low` to its domino trigger threshold so
/// the alert and the trigger read one number.
pub fn set_rule_bound(name: &str, bound: Option<f64>) {
    let rule = rule_by_name(name);
    let mut s = sources().lock().unwrap();
    match bound {
        Some(b) => {
            s.rule_bounds.insert(rule.name, b);
        }
        None => {
            s.rule_bounds.remove(rule.name);
        }
    }
}

/// Explicit bound for a source, if one was set ([`set_source_bound`]).
/// The `/healthz` readiness path only degrades on explicit bounds.
pub fn source_bound(name: &str) -> Option<f64> {
    sources().lock().unwrap().source_bounds.get(name).copied()
}

/// Sample every live registration of one source, pruning dead ones.
/// Returns `(detail, value)` pairs. Panics on an undeclared source.
pub fn sample_source(name: &str) -> Vec<(String, f64)> {
    source_what(name);
    let mut s = sources().lock().unwrap();
    let Some(entries) = s.sources.get_mut(name) else { return Vec::new() };
    let mut out = Vec::new();
    entries.retain(|(detail, f)| match f() {
        Some(v) => {
            out.push((detail.clone(), v));
            true
        }
        None => false,
    });
    out
}

fn effective_bound(rule: &Rule) -> f64 {
    let s = sources().lock().unwrap();
    if let Some(b) = s.rule_bounds.get(rule.name) {
        return *b;
    }
    if let Query::SourceAbove(src) | Query::SourceBelow(src) = rule.query {
        if let Some(b) = s.source_bounds.get(src) {
            return *b;
        }
    }
    rule.bound
}

// ---------------------------------------------------------------------------
// Rule evaluator (pending -> firing -> resolved)
// ---------------------------------------------------------------------------

/// Lifecycle state of one rule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum State {
    /// Not breaching.
    Ok,
    /// Breaching, but for fewer than `for_ticks` evaluations.
    Pending,
    /// Breaching past the hysteresis window.
    Firing,
}

impl State {
    /// Lower-case label used in JSON and the gauge value (0/1/2).
    pub fn as_str(self) -> &'static str {
        match self {
            State::Ok => "ok",
            State::Pending => "pending",
            State::Firing => "firing",
        }
    }

    fn gauge(self) -> u64 {
        match self {
            State::Ok => 0,
            State::Pending => 1,
            State::Firing => 2,
        }
    }
}

/// One rule's status after an evaluation ([`evaluate`]).
#[derive(Debug, Clone)]
pub struct RuleStatus {
    /// Declared rule name.
    pub rule: &'static str,
    /// Declared severity.
    pub severity: Severity,
    /// Current lifecycle state.
    pub state: State,
    /// Last measured value (`None` when the input has no live samples
    /// yet — e.g. a rate rule's baseline tick).
    pub value: Option<f64>,
    /// Effective bound (explicit override or declared default).
    pub bound: f64,
    /// Consecutive breaching evaluations.
    pub breaches: u64,
}

struct RuleRuntime {
    /// Exported as `weips_alert_state{rule,severity}` (0/1/2).
    gauge: Arc<AtomicU64>,
    breaches: u64,
    state: State,
    /// `(counter total, mono_ns)` of the previous rate sample.
    prev_rate: Option<(f64, u64)>,
}

struct EngineState {
    rules: Vec<RuleRuntime>,
    /// Last evaluation's statuses, for `/alerts` rendering (GET does not
    /// re-evaluate; cadence is owned by the ticker / control tick).
    snapshot: Vec<RuleStatus>,
    evals: u64,
    last_eval_ms: u64,
}

fn engine() -> &'static Mutex<EngineState> {
    static E: OnceLock<Mutex<EngineState>> = OnceLock::new();
    E.get_or_init(|| {
        let rules: Vec<RuleRuntime> = RULES
            .iter()
            .map(|rule| {
                let gauge = Arc::new(AtomicU64::new(0));
                let reader = gauge.clone();
                metrics::register_fn(
                    "weips_alert_state",
                    &[
                        ("rule", rule.name.to_string()),
                        ("severity", rule.severity.as_str().to_string()),
                    ],
                    Box::new(move || Some(reader.load(Ordering::Relaxed) as f64)),
                );
                RuleRuntime { gauge, breaches: 0, state: State::Ok, prev_rate: None }
            })
            .collect();
        Mutex::new(EngineState { rules, snapshot: Vec::new(), evals: 0, last_eval_ms: 0 })
    })
}

/// Evaluate every declared rule once, journaling state transitions and
/// recording the evaluator's own cost in
/// `weips_alert_eval_duration_seconds{role}`. Read-only against the
/// pipeline: wire bytes are identical with the evaluator on or off.
pub fn evaluate(role: &str) -> Vec<RuleStatus> {
    let start = mono_ns();
    let mut transitions: Vec<(&'static str, &'static str, String, u64)> = Vec::new();
    let statuses = {
        let mut eng = engine().lock().unwrap();
        let mut statuses = Vec::with_capacity(RULES.len());
        for (rule, rt) in RULES.iter().zip(eng.rules.iter_mut()) {
            let value = measure(rule, rt);
            let bound = effective_bound(rule);
            let breach = match (rule.query, value) {
                (Query::SourceBelow(_), Some(v)) => v < bound,
                (_, Some(v)) => v > bound,
                (_, None) => false,
            };
            let prev = rt.state;
            if breach {
                rt.breaches += 1;
                rt.state =
                    if rt.breaches > rule.for_ticks { State::Firing } else { State::Pending };
            } else {
                rt.breaches = 0;
                rt.state = State::Ok;
            }
            rt.gauge.store(rt.state.gauge(), Ordering::Relaxed);
            if rt.state != prev {
                let kind = match rt.state {
                    State::Pending => "alert_pending",
                    State::Firing => "alert_firing",
                    State::Ok => "alert_resolved",
                };
                let detail = format!(
                    "role={role} state={} value={} bound={} breaches={}",
                    rt.state.as_str(),
                    value.map_or("none".to_string(), fmt_num),
                    fmt_num(bound),
                    rt.breaches,
                );
                // Latency alerts cite the most recent sampled batch via
                // the histogram's exemplar — the journal entry links
                // straight to /trace/<id>.
                let trace_id = match rule.query {
                    Query::P99Above(fam) => metrics::exemplar_trace_id(fam).unwrap_or(0),
                    _ => 0,
                };
                transitions.push((kind, rule.name, detail, trace_id));
            }
            statuses.push(RuleStatus {
                rule: rule.name,
                severity: rule.severity,
                state: rt.state,
                value,
                bound,
                breaches: rt.breaches,
            });
        }
        eng.snapshot = statuses.clone();
        eng.evals += 1;
        eng.last_eval_ms = now_ms();
        statuses
    };
    // Journal outside the engine lock: journal() takes ring + file locks.
    for (kind, name, detail, trace_id) in transitions {
        journal(kind, name, &detail, trace_id);
    }
    metrics::histogram("weips_alert_eval_duration_seconds", &[("role", role.to_string())])
        .record(mono_ns().saturating_sub(start));
    statuses
}

fn measure(rule: &Rule, rt: &mut RuleRuntime) -> Option<f64> {
    match rule.query {
        Query::SourceAbove(src) => {
            sample_source(src).into_iter().map(|(_, v)| v).fold(None, |acc: Option<f64>, v| {
                Some(acc.map_or(v, |m| m.max(v)))
            })
        }
        Query::SourceBelow(src) => {
            sample_source(src).into_iter().map(|(_, v)| v).fold(None, |acc: Option<f64>, v| {
                Some(acc.map_or(v, |m| m.min(v)))
            })
        }
        Query::RateAbove(fam) => {
            let total = metrics::family_total(fam);
            let now = mono_ns();
            let prev = rt.prev_rate;
            rt.prev_rate = total.map(|t| (t, now));
            match (prev, total) {
                (Some((pt, pn)), Some(t)) if now > pn => {
                    Some((t - pt).max(0.0) / ((now - pn) as f64 / 1e9))
                }
                _ => None,
            }
        }
        Query::P99Above(fam) => metrics::family_quantile(fam, 0.99),
    }
}

/// Prometheus-style number formatting for journal details.
fn fmt_num(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:.6}")
    }
}

/// Render the last evaluation as the `/alerts` JSON body.
pub fn render_alerts_json() -> String {
    let eng = engine().lock().unwrap();
    let mut out = String::with_capacity(1024);
    out.push_str(&format!(
        "{{\"ts_ms\":{},\"evals\":{},\"rules\":[",
        eng.last_eval_ms, eng.evals
    ));
    for (i, s) in eng.snapshot.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let value = match s.value {
            Some(v) if v.is_finite() => format!("{v}"),
            _ => "null".to_string(),
        };
        out.push_str(&format!(
            "{{\"rule\":\"{}\",\"severity\":\"{}\",\"state\":\"{}\",\"value\":{},\
             \"bound\":{},\"breaches\":{}}}",
            s.rule,
            s.severity.as_str(),
            s.state.as_str(),
            value,
            s.bound,
            s.breaches,
        ));
    }
    out.push_str("]}");
    out
}

// ---------------------------------------------------------------------------
// Structured event journal (lock-striped ring + optional WAL file)
// ---------------------------------------------------------------------------

/// One journaled event.
#[derive(Debug, Clone)]
pub struct Event {
    /// Monotonic sequence number (global order across stripes).
    pub seq: u64,
    /// Wall-clock time of the event.
    pub ts_ms: u64,
    /// Declared kind ([`KINDS`]).
    pub kind: &'static str,
    /// Event name (rule name, subsystem, or lifecycle step).
    pub name: String,
    /// Free-form context (`k=v` pairs by convention).
    pub detail: String,
    /// Correlated trace id (0 = none; see [`crate::trace`]).
    pub trace_id: u64,
}

const STRIPES: usize = 8;
const PER_STRIPE: usize = 256;

struct JournalState {
    stripes: Vec<Mutex<VecDeque<Event>>>,
    seq: AtomicU64,
    /// Optional WAL-style persistence: events append to
    /// `<dir>/events.wal` as JSON lines, replayed on [`set_journal_dir`].
    file: Mutex<Option<File>>,
}

fn journal_state() -> &'static JournalState {
    static J: OnceLock<JournalState> = OnceLock::new();
    J.get_or_init(|| JournalState {
        stripes: (0..STRIPES).map(|_| Mutex::new(VecDeque::new())).collect(),
        seq: AtomicU64::new(0),
        file: Mutex::new(None),
    })
}

/// Record one event. `kind` must be declared in [`KINDS`]; `trace_id` 0
/// means no correlated trace.
pub fn journal(kind: &'static str, name: &str, detail: &str, trace_id: u64) {
    kind_index(kind);
    let js = journal_state();
    let seq = js.seq.fetch_add(1, Ordering::Relaxed);
    let ev = Event {
        seq,
        ts_ms: now_ms(),
        kind,
        name: name.to_string(),
        detail: detail.to_string(),
        trace_id,
    };
    if let Some(f) = js.file.lock().unwrap().as_mut() {
        // Best-effort durability: a full disk must not take down the
        // data path, so write errors are swallowed (the ring still has
        // the event).
        let line = format!("{}\n", event_json(&ev));
        let _ = f.write_all(line.as_bytes()).and_then(|_| f.flush());
    }
    let mut stripe = js.stripes[(seq % STRIPES as u64) as usize].lock().unwrap();
    if stripe.len() == PER_STRIPE {
        stripe.pop_front();
    }
    stripe.push_back(ev);
}

/// The most recent `limit` events, newest first.
pub fn recent_events(limit: usize) -> Vec<Event> {
    let js = journal_state();
    let mut all: Vec<Event> = Vec::new();
    for stripe in &js.stripes {
        all.extend(stripe.lock().unwrap().iter().cloned());
    }
    all.sort_by(|a, b| b.seq.cmp(&a.seq));
    all.truncate(limit);
    all
}

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\")
        .replace('"', "\\\"")
        .replace('\n', "\\n")
        .replace('\r', "\\r")
        .replace('\t', "\\t")
}

fn event_json(ev: &Event) -> String {
    let mut out = format!(
        "{{\"seq\":{},\"ts_ms\":{},\"kind\":\"{}\",\"name\":\"{}\",\"detail\":\"{}\"",
        ev.seq,
        ev.ts_ms,
        ev.kind,
        esc(&ev.name),
        esc(&ev.detail),
    );
    if ev.trace_id != 0 {
        out.push_str(&format!(",\"trace_id\":\"{}\"", crate::trace::format_id(ev.trace_id)));
    }
    out.push('}');
    out
}

/// Render the newest `limit` events as the `/events` JSON body.
pub fn render_events_json(limit: usize) -> String {
    let events = recent_events(limit);
    let body: Vec<String> = events.iter().map(event_json).collect();
    format!("{{\"events\":[{}]}}", body.join(","))
}

/// Enable (`Some(dir)`) or disable (`None`) WAL-backed journal
/// persistence. Existing events in `<dir>/events.wal` are replayed into
/// the ring (torn tails — partial last lines — are skipped) and the seq
/// counter resumes past them, so a restarted role keeps its history.
pub fn set_journal_dir(dir: Option<&Path>) -> std::io::Result<()> {
    let js = journal_state();
    let Some(dir) = dir else {
        *js.file.lock().unwrap() = None;
        return Ok(());
    };
    std::fs::create_dir_all(dir)?;
    let path: PathBuf = dir.join("events.wal");
    let mut existing = String::new();
    if let Ok(mut f) = File::open(&path) {
        // Invalid UTF-8 (torn multi-byte tail) degrades to an empty
        // replay rather than an error.
        let mut bytes = Vec::new();
        f.read_to_end(&mut bytes)?;
        existing = String::from_utf8_lossy(&bytes).into_owned();
    }
    let mut max_seq = 0u64;
    for line in existing.lines() {
        let Some(ev) = parse_event(line) else { continue };
        max_seq = max_seq.max(ev.seq + 1);
        let mut stripe = js.stripes[(ev.seq % STRIPES as u64) as usize].lock().unwrap();
        if stripe.len() == PER_STRIPE {
            stripe.pop_front();
        }
        stripe.push_back(ev);
    }
    js.seq.fetch_max(max_seq, Ordering::Relaxed);
    let file = OpenOptions::new().create(true).append(true).open(&path)?;
    *js.file.lock().unwrap() = Some(file);
    Ok(())
}

fn parse_event(line: &str) -> Option<Event> {
    let doc = Json::parse(line).ok()?;
    let kind = doc.get("kind")?.as_str()?;
    // Unknown kinds (a newer build's journal) are skipped, not a panic.
    let kind = *KINDS.iter().find(|k| **k == kind)?;
    Some(Event {
        seq: doc.get("seq")?.as_f64()? as u64,
        ts_ms: doc.get("ts_ms")?.as_f64()? as u64,
        kind,
        name: doc.get("name")?.as_str()?.to_string(),
        detail: doc.get("detail")?.as_str()?.to_string(),
        trace_id: doc
            .get("trace_id")
            .and_then(|t| t.as_str())
            .and_then(crate::trace::parse_id)
            .unwrap_or(0),
    })
}

// ---------------------------------------------------------------------------
// Evaluator ticker (remote roles)
// ---------------------------------------------------------------------------

/// Background evaluator thread handle; dropping it stops and joins the
/// thread. The local coordinator evaluates from its control tick
/// instead.
pub struct Ticker {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl Drop for Ticker {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Start a background evaluator ticking every `every_ms` (0 disables —
/// returns `None`).
pub fn spawn_ticker(role: &str, every_ms: u64) -> Option<Ticker> {
    if every_ms == 0 {
        return None;
    }
    let stop = Arc::new(AtomicBool::new(false));
    let flag = stop.clone();
    let role = role.to_string();
    let handle = std::thread::Builder::new()
        .name("weips-alerts".to_string())
        .spawn(move || {
            while !flag.load(Ordering::Relaxed) {
                evaluate(&role);
                // Sleep in short slices so Drop joins promptly.
                let mut slept = 0u64;
                while slept < every_ms && !flag.load(Ordering::Relaxed) {
                    let step = (every_ms - slept).min(25);
                    std::thread::sleep(std::time::Duration::from_millis(step));
                    slept += step;
                }
            }
        })
        .expect("spawn alerts ticker");
    Some(Ticker { stop, handle: Some(handle) })
}

// ---------------------------------------------------------------------------
// Test support
// ---------------------------------------------------------------------------

/// Reset the engine, journal ring, sources, and bound overrides —
/// rebuilding a cluster in one process (tests, benches) starts clean.
/// Persistence stays configured.
pub fn clear() {
    let mut eng = engine().lock().unwrap();
    for rt in &mut eng.rules {
        rt.breaches = 0;
        rt.state = State::Ok;
        rt.prev_rate = None;
        rt.gauge.store(0, Ordering::Relaxed);
    }
    eng.snapshot.clear();
    eng.evals = 0;
    eng.last_eval_ms = 0;
    drop(eng);
    let mut s = sources().lock().unwrap();
    s.sources.clear();
    s.source_bounds.clear();
    s.rule_bounds.clear();
    drop(s);
    let js = journal_state();
    for stripe in &js.stripes {
        stripe.lock().unwrap().clear();
    }
}

/// Serialize tests that touch the global engine/journal/sources.
#[cfg(test)]
pub fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static L: OnceLock<Mutex<()>> = OnceLock::new();
    L.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state_of<'a>(statuses: &'a [RuleStatus], rule: &str) -> &'a RuleStatus {
        statuses.iter().find(|s| s.rule == rule).unwrap()
    }

    /// Satellite: readiness and alerting share one declaration — every
    /// `/healthz` probe must be a declared source AND have a rule
    /// reading it, so the two bound sets cannot drift.
    #[test]
    fn health_probes_and_rules_share_declarations() {
        for (name, what) in crate::metrics::HEALTH_PROBES {
            assert_eq!(
                source_what(name),
                *what,
                "health probe {name} must be declared in alerts::SOURCES with the same text"
            );
            assert!(
                RULES.iter().any(|r| matches!(
                    r.query,
                    Query::SourceAbove(s) | Query::SourceBelow(s) if s == *name
                )),
                "health probe {name} has no alert rule reading it"
            );
        }
    }

    #[test]
    fn rule_names_unique_and_families_declared() {
        for (i, r) in RULES.iter().enumerate() {
            assert!(
                !RULES[..i].iter().any(|o| o.name == r.name),
                "duplicate rule {}",
                r.name
            );
            match r.query {
                Query::SourceAbove(s) | Query::SourceBelow(s) => {
                    source_what(s);
                }
                Query::RateAbove(f) | Query::P99Above(f) => {
                    assert!(
                        metrics::DESCRIPTORS.iter().any(|d| d.name == f),
                        "rule {} reads undeclared family {f}",
                        r.name
                    );
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "not declared in SOURCES")]
    fn undeclared_source_panics() {
        register_source("made_up_source", "x".to_string(), Box::new(|| Some(1.0)));
    }

    #[test]
    #[should_panic(expected = "not declared in KINDS")]
    fn undeclared_event_kind_panics() {
        journal("made_up_kind", "x", "y", 0);
    }

    #[test]
    fn source_rule_walks_pending_firing_resolved() {
        let _g = test_lock();
        clear();
        let lag = Arc::new(AtomicU64::new(5_000_000_000));
        let weak = Arc::downgrade(&lag);
        register_source(
            "scatter_lag_records",
            "unit-test".to_string(),
            Box::new(move || weak.upgrade().map(|v| v.load(Ordering::Relaxed) as f64)),
        );
        set_source_bound("scatter_lag_records", Some(1e9));
        // for_ticks = 2: two pending evaluations, firing on the third.
        let s1 = evaluate("test");
        assert_eq!(state_of(&s1, "scatter_lag_high").state, State::Pending);
        let s2 = evaluate("test");
        assert_eq!(state_of(&s2, "scatter_lag_high").state, State::Pending);
        let s3 = evaluate("test");
        assert_eq!(state_of(&s3, "scatter_lag_high").state, State::Firing);
        assert_eq!(state_of(&s3, "scatter_lag_high").value, Some(5e9));
        // The exported gauge tracks the state machine.
        let text = metrics::render();
        assert!(
            text.contains("weips_alert_state{rule=\"scatter_lag_high\",severity=\"warning\"} 2"),
            "missing firing gauge in:\n{text}"
        );
        // Recovery resolves and journals the full lifecycle.
        lag.store(0, Ordering::Relaxed);
        let s4 = evaluate("test");
        assert_eq!(state_of(&s4, "scatter_lag_high").state, State::Ok);
        let kinds: Vec<&str> = recent_events(64)
            .into_iter()
            .filter(|e| e.name == "scatter_lag_high")
            .map(|e| e.kind)
            .collect();
        // Newest first.
        assert_eq!(kinds, vec!["alert_resolved", "alert_firing", "alert_pending"]);
        clear();
    }

    #[test]
    fn window_auc_rule_fires_on_first_breach_and_ignores_empty_monitor() {
        let _g = test_lock();
        clear();
        let auc = Arc::new(Mutex::new(None::<f64>));
        let reader = auc.clone();
        register_source(
            "model_window_auc",
            "unit-test".to_string(),
            Box::new(move || *reader.lock().unwrap()),
        );
        set_rule_bound("window_auc_low", Some(0.6));
        // No samples yet: the source reports nothing, the rule stays Ok
        // (a cold monitor must not fire a critical alert at startup).
        let s = evaluate("test");
        assert_eq!(state_of(&s, "window_auc_low").state, State::Ok);
        assert_eq!(state_of(&s, "window_auc_low").value, None);
        // AUC collapse: for_ticks = 0 fires on the first breach.
        *auc.lock().unwrap() = Some(0.41);
        let s = evaluate("test");
        assert_eq!(state_of(&s, "window_auc_low").state, State::Firing);
        assert_eq!(state_of(&s, "window_auc_low").bound, 0.6);
        clear();
    }

    #[test]
    fn rate_rule_arms_baseline_on_first_eval() {
        let _g = test_lock();
        clear();
        let c = metrics::counter(
            "weips_rpc_class_shed_total",
            &[("server", "alerts-ut".to_string()), ("class", "bulk".to_string())],
        );
        let s1 = evaluate("test");
        assert_eq!(
            state_of(&s1, "qos_shed_rate_high").value,
            None,
            "first eval is the baseline"
        );
        c.fetch_add(10_000, Ordering::Relaxed);
        std::thread::sleep(std::time::Duration::from_millis(5));
        let s2 = evaluate("test");
        let rate = state_of(&s2, "qos_shed_rate_high").value.unwrap();
        assert!(rate > 0.0, "rate should be positive, got {rate}");
        clear();
    }

    #[test]
    fn explicit_bounds_override_defaults_and_clear_back() {
        let _g = test_lock();
        clear();
        let rule = rule_by_name("wal_unsynced_high");
        assert_eq!(effective_bound(rule), 1_000_000.0);
        set_source_bound("wal_unsynced_appends", Some(42.0));
        assert_eq!(effective_bound(rule), 42.0);
        // Rule-level override beats the source bound.
        set_rule_bound("wal_unsynced_high", Some(7.0));
        assert_eq!(effective_bound(rule), 7.0);
        set_rule_bound("wal_unsynced_high", None);
        set_source_bound("wal_unsynced_appends", None);
        assert_eq!(effective_bound(rule), 1_000_000.0);
        clear();
    }

    #[test]
    fn journal_ring_overwrites_oldest_without_growing() {
        let _g = test_lock();
        clear();
        for i in 0..(STRIPES * PER_STRIPE + 500) {
            journal("checkpoint", "ring-test", &format!("i={i}"), 0);
        }
        let all = recent_events(usize::MAX);
        assert!(all.len() <= STRIPES * PER_STRIPE);
        // Newest first, contiguous seqs at the top.
        assert!(all[0].seq > all[1].seq);
        assert_eq!(all[0].detail, format!("i={}", STRIPES * PER_STRIPE + 499));
        clear();
    }

    #[test]
    fn events_render_and_reparse_with_trace_ids() {
        let _g = test_lock();
        clear();
        journal("degradation", "rpc_poll_mode", "requested=uring engaged=event", 0x2a);
        let body = render_events_json(4);
        let doc = Json::parse(&body).unwrap();
        let events = doc.get("events").unwrap().as_arr().unwrap();
        let ev = &events[0];
        assert_eq!(ev.get("kind").unwrap().as_str(), Some("degradation"));
        assert_eq!(ev.get("name").unwrap().as_str(), Some("rpc_poll_mode"));
        assert_eq!(ev.get("trace_id").unwrap().as_str(), Some("000000000000002a"));
        clear();
    }

    #[test]
    fn journal_persists_and_replays_across_reopen() {
        let _g = test_lock();
        clear();
        let dir = std::env::temp_dir()
            .join(format!("weips-alerts-persist-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        set_journal_dir(Some(dir.as_path())).unwrap();
        journal("recovery", "slave_restart", "shard=0 replica=1", 0);
        journal("reshard", "migrate_slots", "moved=16", 7);
        set_journal_dir(None).unwrap();
        clear();
        assert!(recent_events(8).is_empty());
        // Reopen: the WAL file replays into the ring, seq resumes past it.
        set_journal_dir(Some(dir.as_path())).unwrap();
        let replayed = recent_events(8);
        assert_eq!(replayed.len(), 2);
        assert_eq!(replayed[0].name, "migrate_slots");
        assert_eq!(replayed[0].trace_id, 7);
        assert_eq!(replayed[1].detail, "shard=0 replica=1");
        journal("checkpoint", "after-replay", "", 0);
        assert!(recent_events(1)[0].seq > replayed[0].seq);
        set_journal_dir(None).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
        clear();
    }

    #[test]
    fn alerts_json_reports_last_evaluation() {
        let _g = test_lock();
        clear();
        evaluate("test");
        let doc = Json::parse(&render_alerts_json()).unwrap();
        assert!(doc.get("evals").unwrap().as_f64().unwrap() >= 1.0);
        let rules = doc.get("rules").unwrap().as_arr().unwrap();
        assert_eq!(rules.len(), RULES.len());
        for (r, decl) in rules.iter().zip(RULES) {
            assert_eq!(r.get("rule").unwrap().as_str(), Some(decl.name));
            assert_eq!(r.get("severity").unwrap().as_str(), Some(decl.severity.as_str()));
        }
        clear();
    }

    #[test]
    fn ticker_evaluates_and_stops_on_drop() {
        let _g = test_lock();
        clear();
        assert!(spawn_ticker("test", 0).is_none());
        let before = engine().lock().unwrap().evals;
        let t = spawn_ticker("test", 1).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(30));
        drop(t);
        let after = engine().lock().unwrap().evals;
        assert!(after > before, "ticker never evaluated");
        clear();
    }
}
