//! Scheduler (§3.3): lifecycle + metadata + checkpoint orchestration.
//!
//! "The scheduler is the core scheduling component of the entire cluster,
//! which is responsible for the lifecycle management of the entire system
//! ... maintains global metadata and is stateless," with consistency
//! delegated to the coordination store ([`MetaStore`], our ZK/etcd).
//!
//! Responsibilities implemented here:
//! - node registry: ephemeral registrations kept alive by heartbeats,
//!   failure detection via session expiry;
//! - checkpoint orchestration (§4.2.1): **randomly jittered trigger** so
//!   shards don't aggregate save traffic, **asynchronous saving** through
//!   a thread pool, manifest finalization with queue offsets + metric,
//!   local GC and periodic remote replication;
//! - version counter for the domino downgrade's lineage.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::meta::MetaStore;
use crate::server::master::MasterShard;
use crate::storage::incremental::{self, IncrPolicy};
use crate::storage::{CheckpointStore, CkptKind, CkptManifest};
use crate::util::clock::Clock;
use crate::util::{Rng, ThreadPool};
use crate::{Error, Result};

/// Checkpoint policy knobs (paper §4.2.1c: per-model configurable).
#[derive(Debug, Clone)]
pub struct CkptPolicy {
    /// Mean interval between checkpoints (ms).
    pub interval_ms: u64,
    /// Random jitter fraction of the interval (0.3 = ±30%).
    pub jitter: f64,
    /// Local versions to keep.
    pub keep_local: usize,
    /// Replicate every k-th version to the remote tier (0 = never).
    pub remote_every: u64,
}

impl Default for CkptPolicy {
    fn default() -> Self {
        CkptPolicy { interval_ms: 10_000, jitter: 0.3, keep_local: 5, remote_every: 4 }
    }
}

/// A registered node's view.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeInfo {
    pub name: String,
    pub role: String,
    pub session: u64,
}

/// The scheduler.
pub struct Scheduler {
    pub meta: MetaStore,
    pub store: Arc<CheckpointStore>,
    model: String,
    policy: CkptPolicy,
    clock: Arc<dyn Clock>,
    pool: ThreadPool,
    next_version: AtomicU64,
    last_ckpt_ms: AtomicU64,
    next_due_ms: AtomicU64,
    rng: Mutex<Rng>,
    /// Incremental chain policy ([`Self::checkpoint_incremental`]).
    incr: IncrPolicy,
    /// Force the next incremental checkpoint to reseed a base (set after
    /// a downgrade: the rolled-back state has no delta lineage).
    force_base: AtomicBool,
    pub checkpoints_taken: AtomicU64,
    /// Router whose slot map gets sealed into every manifest
    /// ([`Self::set_route_source`]); `None` seals epoch 0 (uniform map).
    route_source: Mutex<Option<crate::sync::Router>>,
}

impl Scheduler {
    /// New scheduler for `model`.
    pub fn new(
        meta: MetaStore,
        store: Arc<CheckpointStore>,
        model: &str,
        policy: CkptPolicy,
        clock: Arc<dyn Clock>,
    ) -> Scheduler {
        // Resume the version counter from whatever storage already has.
        let start_version = store.latest_version(model).unwrap_or(0);
        let now = clock.now_ms();
        let s = Scheduler {
            meta,
            store,
            model: model.to_string(),
            policy,
            clock,
            pool: ThreadPool::new(2, "ckpt"),
            next_version: AtomicU64::new(start_version + 1),
            last_ckpt_ms: AtomicU64::new(now),
            next_due_ms: AtomicU64::new(0),
            rng: Mutex::new(Rng::new(now ^ 0x5c4ed)),
            incr: IncrPolicy::default(),
            force_base: AtomicBool::new(false),
            checkpoints_taken: AtomicU64::new(0),
            route_source: Mutex::new(None),
        };
        s.schedule_next(now);
        s
    }

    /// Override the incremental chain policy (call before first use).
    pub fn set_incr_policy(&mut self, policy: IncrPolicy) {
        self.incr = policy;
    }

    /// Force the next [`Self::checkpoint_incremental`] to reseed a base
    /// chain (after a downgrade the rolled-back state has no lineage to
    /// delta against).
    pub fn force_base_next(&self) {
        self.force_base.store(true, Ordering::SeqCst);
    }

    /// Seal this router's slot map into every future manifest, so a
    /// cold-started cluster (no live scheduler metadata) can restore the
    /// routing it was checkpointed under before replaying state.
    pub fn set_route_source(&self, router: crate::sync::Router) {
        *self.route_source.lock().unwrap() = Some(router);
    }

    /// (routing epoch, encoded slot map) for the next manifest. Epoch 0
    /// (the implicit uniform map) seals an empty payload — recovery
    /// rebuilds it from the shard count alone.
    fn route_snapshot(&self) -> (u64, Vec<u8>) {
        match self.route_source.lock().unwrap().as_ref() {
            Some(r) => {
                let map = r.snapshot();
                if map.epoch > 0 {
                    (map.epoch, map.to_bytes())
                } else {
                    (0, Vec::new())
                }
            }
            None => (0, Vec::new()),
        }
    }

    // -- node registry --------------------------------------------------------

    /// Register a node; returns its heartbeat session.
    pub fn register(&self, role: &str, name: &str, ttl_ms: u64) -> Result<NodeInfo> {
        let session = self.meta.open_session(ttl_ms);
        self.meta
            .put_ephemeral(session, &format!("/nodes/{role}/{name}"), name.as_bytes().to_vec())?;
        Ok(NodeInfo { name: name.to_string(), role: role.to_string(), session })
    }

    /// Heartbeat a registered node.
    pub fn heartbeat(&self, node: &NodeInfo) -> Result<()> {
        self.meta.heartbeat(node.session)
    }

    /// Expire dead sessions; returns the node keys that disappeared
    /// (failure detection input for partial recovery).
    pub fn detect_failures(&self) -> Vec<String> {
        let before: Vec<String> = self.meta.list("/nodes/").into_iter().map(|(k, _, _)| k).collect();
        let expired = self.meta.expire_sessions();
        if expired.is_empty() {
            return Vec::new();
        }
        let after: Vec<String> = self.meta.list("/nodes/").into_iter().map(|(k, _, _)| k).collect();
        before.into_iter().filter(|k| !after.contains(k)).collect()
    }

    /// Nodes currently registered under a role.
    pub fn nodes(&self, role: &str) -> Vec<String> {
        self.meta
            .list(&format!("/nodes/{role}/"))
            .into_iter()
            .map(|(k, _, _)| k.rsplit('/').next().unwrap_or("").to_string())
            .collect()
    }

    // -- checkpoint orchestration (§4.2.1) -------------------------------------

    fn schedule_next(&self, now: u64) {
        let jitter_span = (self.policy.interval_ms as f64 * self.policy.jitter) as u64;
        let jitter = if jitter_span == 0 {
            0
        } else {
            let mut rng = self.rng.lock().unwrap();
            rng.gen_range(2 * jitter_span + 1)
        };
        let due = now + self.policy.interval_ms - jitter_span + jitter;
        self.next_due_ms.store(due, Ordering::Release);
    }

    /// True when the (jittered) checkpoint timer has fired.
    pub fn checkpoint_due(&self) -> bool {
        self.clock.now_ms() >= self.next_due_ms.load(Ordering::Acquire)
    }

    /// Take a full-cluster checkpoint: saves every master shard in
    /// parallel (asynchronous saving), finalizes the manifest (with queue
    /// offsets + metric), GCs local versions and replicates per policy.
    /// Returns the new version.
    pub fn checkpoint_now(
        &self,
        masters: &[Arc<MasterShard>],
        queue_offsets: Vec<u64>,
        metric: f64,
    ) -> Result<u64> {
        let version = self.next_version.fetch_add(1, Ordering::SeqCst);
        // Full checkpoints are epoch fences too: record the cuts so a
        // later incremental delta can parent this version.
        let cuts: Vec<u64> = masters.iter().map(|m| m.cut_epoch()).collect();
        let errors = Arc::new(Mutex::new(Vec::new()));
        for m in masters {
            let m = m.clone();
            let store = self.store.clone();
            let errors = errors.clone();
            let model = self.model.clone();
            self.pool.execute(move || {
                let snap = m.snapshot();
                if let Err(e) = store.save_shard(&model, version, m.shard_id, &snap) {
                    errors.lock().unwrap().push(e.to_string());
                }
            });
        }
        self.pool.join();
        let errs = errors.lock().unwrap();
        if !errs.is_empty() {
            return Err(Error::Checkpoint(format!("shard saves failed: {}", errs.join("; "))));
        }
        drop(errs);
        let (route_epoch, slot_map) = self.route_snapshot();
        self.store.write_manifest(&CkptManifest {
            model: self.model.clone(),
            version,
            created_ms: self.clock.now_ms(),
            num_shards: masters.len() as u32,
            queue_offsets,
            metric,
            kind: CkptKind::Base,
            parent: 0,
            epochs: cuts.clone(),
            wal_offsets: Vec::new(),
            route_epoch,
            slot_map,
        })?;
        for (m, cut) in masters.iter().zip(&cuts) {
            m.prune_dirty(*cut);
        }
        if self.policy.remote_every > 0 && version % self.policy.remote_every == 0 {
            self.store.replicate_to_remote(&self.model, version)?;
        }
        // Chain-aware GC even in full mode: on an all-base store it keeps
        // exactly the newest `keep_local` versions (same as the old
        // newest-N sweep), but on a store that still holds incremental
        // chains (ckpt_mode flipped) it never deletes a base out from
        // under its deltas.
        let _ = incremental::gc_chains(&self.store, &self.model, self.policy.keep_local);
        self.finish_checkpoint(version);
        Ok(version)
    }

    /// Incremental checkpoint (§4.2.1 + Monolith-style chains): decide
    /// base vs delta by chain length, cut every shard's epoch, save one
    /// chunk per shard on the checkpoint pool (deltas collect one stripe
    /// at a time under stripe read locks — training never globally
    /// stalls), seal the chained manifest, prune sealed tombstones,
    /// replicate the sealed chunks and GC whole chains. `wal_offsets`
    /// are the WAL log-end offsets at seal time (empty without a WAL).
    /// Returns (version, kind, per-shard epoch cuts).
    pub fn checkpoint_incremental(
        &self,
        masters: &[Arc<MasterShard>],
        queue_offsets: Vec<u64>,
        wal_offsets: Vec<u64>,
        metric: f64,
    ) -> Result<(u64, CkptKind, Vec<u64>)> {
        let version = self.next_version.fetch_add(1, Ordering::SeqCst);
        let (mut kind, parent) = incremental::plan_next(&self.store, &self.model, &self.incr);
        if self.force_base.swap(false, Ordering::SeqCst) {
            kind = CkptKind::Base;
        }
        let parent_version = match (kind, &parent) {
            (CkptKind::Delta, Some(p)) => p.version,
            _ => 0,
        };
        // Cut first: the collection below captures everything stamped at
        // or before its cut; post-cut writes belong to the next window.
        let cuts: Vec<u64> = masters.iter().map(|m| m.cut_epoch()).collect();
        let errors = Arc::new(Mutex::new(Vec::new()));
        for (i, m) in masters.iter().enumerate() {
            let m = m.clone();
            let store = self.store.clone();
            let errors = errors.clone();
            let model = self.model.clone();
            let since = match (kind, &parent) {
                (CkptKind::Delta, Some(p)) => p.epochs.get(i).copied().unwrap_or(0),
                _ => 0,
            };
            self.pool.execute(move || {
                let result = match kind {
                    CkptKind::Base => {
                        store.save_chunk(&model, version, m.shard_id, kind, &m.snapshot())
                    }
                    CkptKind::Delta => {
                        let chunk = m.encode_delta(since);
                        store.save_chunk(&model, version, m.shard_id, kind, &chunk.bytes)
                    }
                };
                if let Err(e) = result {
                    errors.lock().unwrap().push(e.to_string());
                }
            });
        }
        self.pool.join();
        let errs = errors.lock().unwrap();
        if !errs.is_empty() {
            return Err(Error::Checkpoint(format!("chunk saves failed: {}", errs.join("; "))));
        }
        drop(errs);
        let (route_epoch, slot_map) = self.route_snapshot();
        self.store.write_manifest(&CkptManifest {
            model: self.model.clone(),
            version,
            created_ms: self.clock.now_ms(),
            num_shards: masters.len() as u32,
            queue_offsets,
            metric,
            kind,
            parent: parent_version,
            epochs: cuts.clone(),
            wal_offsets,
            route_epoch,
            slot_map,
        })?;
        // Tombstones sealed through the cut can never be collected again
        // (every future delta's `since` is >= the cut).
        for (m, cut) in masters.iter().zip(&cuts) {
            m.prune_dirty(*cut);
        }
        // Replicate every sealed version: a remote delta without its base
        // is useless, and deltas are small.
        if self.policy.remote_every > 0 {
            self.store.replicate_to_remote(&self.model, version)?;
        }
        if kind == CkptKind::Base {
            let _ = incremental::gc_chains(&self.store, &self.model, self.incr.keep_chains);
        }
        self.finish_checkpoint(version);
        Ok((version, kind, cuts))
    }

    fn finish_checkpoint(&self, version: u64) {
        // Publish the version pointer in metadata.
        self.meta
            .put(&format!("/models/{}/version", self.model), version.to_string().into_bytes());
        let now = self.clock.now_ms();
        self.last_ckpt_ms.store(now, Ordering::Release);
        self.schedule_next(now);
        self.checkpoints_taken.fetch_add(1, Ordering::Relaxed);
        // Rare event, so the registry lookup per checkpoint is fine.
        crate::metrics::counter("weips_checkpoints_total", &[("role", "scheduler".to_string())])
            .fetch_add(1, Ordering::Relaxed);
        crate::alerts::journal(
            "checkpoint",
            "checkpoint_finalized",
            &format!("model {} version v{version}", self.model),
            0,
        );
    }

    /// Latest finalized version.
    pub fn latest_version(&self) -> Option<u64> {
        self.store.latest_version(&self.model)
    }

    // -- elastic resharding (slot-map stewardship) -----------------------------

    /// Publish `map` as the model's authoritative slot assignment
    /// (epoch-guarded through the coordination store: a stale epoch is
    /// rejected, so racing coordinators cannot roll the routing back).
    pub fn publish_slot_map(&self, map: &crate::reshard::SlotMap) -> Result<u64> {
        crate::reshard::publish(&self.meta, &self.model, map)
    }

    /// The published slot map, if any — for orchestrators and bootstrap
    /// tooling (automatic node-restart bootstrap is a ROADMAP follow-up;
    /// `weips slave --consume-all` is the manual escape hatch meanwhile).
    pub fn load_slot_map(&self) -> Option<crate::reshard::SlotMap> {
        crate::reshard::load(&self.meta, &self.model).ok().flatten()
    }

    /// Minimal-disruption rebalance plan toward `target_shards`: only
    /// surplus slots (and everything on retiring shards) move.
    pub fn plan_rebalance(
        &self,
        map: &crate::reshard::SlotMap,
        target_shards: u32,
    ) -> Vec<(u16, u32)> {
        crate::reshard::balance_moves(map, target_shards)
    }

    /// Partial recovery (§4.2.1e): restore exactly one crashed shard from
    /// the newest checkpoint — "the entire cluster will not be restarted,
    /// and only this shard will recover". Chain-aware: a base restores
    /// directly, a delta tip walks base → delta chain. Returns the
    /// recovered version.
    pub fn recover_shard(&self, shard: &Arc<MasterShard>) -> Result<u64> {
        let version = self
            .latest_version()
            .ok_or_else(|| Error::Checkpoint(format!("no checkpoint for {}", self.model)))?;
        shard.restore_chain(&self.store, version, shard.shard_id as usize)?;
        Ok(version)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelKind, ModelSpec};
    use crate::proto::SparsePush;
    use crate::runtime::ModelConfig;
    use crate::util::clock::ManualClock;

    fn spec() -> ModelSpec {
        let cfg = ModelConfig {
            batch_train: 8,
            batch_predict: 2,
            fields: 4,
            dim: 2,
            hidden: 8,
            ftrl_block_rows: 64,
            ftrl_alpha: 0.05,
            ftrl_beta: 1.0,
            ftrl_l1: 1.0,
            ftrl_l2: 1.0,
        };
        ModelSpec::derive("ctr", ModelKind::Lr, &cfg)
    }

    fn setup(interval: u64) -> (Scheduler, Vec<Arc<MasterShard>>, ManualClock, std::path::PathBuf) {
        let clock = ManualClock::new(1_000);
        let base = std::env::temp_dir().join(format!(
            "weips-sched-{}-{:x}",
            std::process::id(),
            crate::util::mono_ns()
        ));
        let store = Arc::new(CheckpointStore::new(base.join("local"), Some(base.join("remote"))));
        let meta = MetaStore::new(Arc::new(clock.clone()));
        let masters: Vec<Arc<MasterShard>> = (0..3)
            .map(|i| {
                Arc::new(MasterShard::new(i, spec(), None, 1, Arc::new(clock.clone())).unwrap())
            })
            .collect();
        let policy = CkptPolicy { interval_ms: interval, jitter: 0.3, keep_local: 2, remote_every: 2 };
        let sched = Scheduler::new(meta, store, "ctr", policy, Arc::new(clock.clone()));
        (sched, masters, clock, base)
    }

    fn push_some(masters: &[Arc<MasterShard>], base: u64) {
        for (i, m) in masters.iter().enumerate() {
            m.sparse_push(&SparsePush {
                model: "ctr".into(),
                table: "w".into(),
                ids: vec![base + i as u64],
                grads: vec![1.5],
            })
            .unwrap();
        }
    }

    #[test]
    fn registry_and_failure_detection() {
        let (sched, _, clock, base) = setup(60_000);
        let m0 = sched.register("master", "m0", 1_000).unwrap();
        let _m1 = sched.register("master", "m1", 60_000).unwrap();
        assert_eq!(sched.nodes("master"), vec!["m0", "m1"]);
        // m0 misses heartbeats.
        clock.advance(2_000);
        let dead = sched.detect_failures();
        assert_eq!(dead, vec!["/nodes/master/m0"]);
        assert_eq!(sched.nodes("master"), vec!["m1"]);
        assert!(sched.heartbeat(&m0).is_err());
        std::fs::remove_dir_all(base).ok();
    }

    #[test]
    fn checkpoint_saves_all_shards_and_manifest() {
        let (sched, masters, _, base) = setup(60_000);
        push_some(&masters, 100);
        let v = sched.checkpoint_now(&masters, vec![7, 8], 0.71).unwrap();
        assert_eq!(v, 1);
        let manifest = sched.store.load_manifest("ctr", v).unwrap();
        assert_eq!(manifest.num_shards, 3);
        assert_eq!(manifest.queue_offsets, vec![7, 8]);
        for m in &masters {
            assert!(sched.store.load_shard("ctr", v, m.shard_id).is_ok());
        }
        // Version pointer published.
        let (val, _) = sched.meta.get("/models/ctr/version").unwrap();
        assert_eq!(val, b"1");
        std::fs::remove_dir_all(base).ok();
    }

    #[test]
    fn jittered_trigger_fires_within_band() {
        let (sched, masters, clock, base) = setup(10_000);
        assert!(!sched.checkpoint_due());
        // Before interval*(1-jitter) it must not be due.
        clock.advance(6_900);
        assert!(!sched.checkpoint_due());
        // After interval*(1+jitter) it must be due.
        clock.advance(6_200);
        assert!(sched.checkpoint_due());
        sched.checkpoint_now(&masters, vec![], 0.5).unwrap();
        assert!(!sched.checkpoint_due()); // rescheduled
        std::fs::remove_dir_all(base).ok();
    }

    #[test]
    fn gc_and_remote_replication_policy() {
        let (sched, masters, _, base) = setup(60_000);
        for i in 0..5 {
            push_some(&masters, 1000 + i);
            sched.checkpoint_now(&masters, vec![], 0.5).unwrap();
        }
        // keep_local=2: locals trimmed, but remote_every=2 replicated v2, v4.
        let versions = sched.store.list_versions("ctr");
        assert!(versions.contains(&4) && versions.contains(&5), "{versions:?}");
        assert!(versions.contains(&2), "remote replica survives gc: {versions:?}");
        assert!(!versions.contains(&1) && !versions.contains(&3), "{versions:?}");
        std::fs::remove_dir_all(base).ok();
    }

    #[test]
    fn partial_recovery_restores_one_shard() {
        let (sched, masters, clock, base) = setup(60_000);
        push_some(&masters, 7);
        let v = sched.checkpoint_now(&masters, vec![], 0.6).unwrap();
        // Shard 1 "crashes": fresh empty shard object.
        let fresh = Arc::new(
            MasterShard::new(1, spec(), None, 1, Arc::new(clock.clone())).unwrap(),
        );
        assert_eq!(fresh.total_rows(), 0);
        let got = sched.recover_shard(&fresh).unwrap();
        assert_eq!(got, v);
        assert_eq!(fresh.total_rows(), masters[1].total_rows());
        std::fs::remove_dir_all(base).ok();
    }

    #[test]
    fn incremental_checkpoints_chain_and_recover() {
        let (mut sched, masters, clock, base) = setup(60_000);
        sched.set_incr_policy(IncrPolicy { base_every: 3, keep_chains: 2 });
        push_some(&masters, 100);
        let (v1, k1, cuts1) = sched.checkpoint_incremental(&masters, vec![], vec![], 0.5).unwrap();
        assert_eq!((v1, k1), (1, CkptKind::Base));
        assert_eq!(cuts1.len(), masters.len());
        push_some(&masters, 200);
        let (v2, k2, _) = sched.checkpoint_incremental(&masters, vec![], vec![], 0.5).unwrap();
        assert_eq!((v2, k2), (2, CkptKind::Delta));
        push_some(&masters, 300);
        let (v3, k3, _) = sched.checkpoint_incremental(&masters, vec![], vec![], 0.5).unwrap();
        assert_eq!((v3, k3), (3, CkptKind::Delta));
        let manifest = sched.store.load_manifest("ctr", v3).unwrap();
        assert_eq!(manifest.kind, CkptKind::Delta);
        assert_eq!(manifest.parent, v2);
        // A fresh shard recovers v3 through base + two deltas,
        // byte-identical to the survivor.
        let reference = masters[1].snapshot();
        let fresh =
            Arc::new(MasterShard::new(1, spec(), None, 1, Arc::new(clock.clone())).unwrap());
        let tip = fresh.restore_chain(&sched.store, v3, 1).unwrap();
        assert_eq!(tip.version, v3);
        assert_eq!(fresh.snapshot(), reference, "chain recovery not byte-identical");
        // Chain is full (3 chunks): the next checkpoint reseeds a base.
        let (_, k4, _) = sched.checkpoint_incremental(&masters, vec![], vec![], 0.5).unwrap();
        assert_eq!(k4, CkptKind::Base);
        // force_base_next overrides a would-be delta.
        push_some(&masters, 400);
        sched.force_base_next();
        let (_, k5, _) = sched.checkpoint_incremental(&masters, vec![], vec![], 0.5).unwrap();
        assert_eq!(k5, CkptKind::Base);
        // Chain-aware recovery through the scheduler facade too.
        let fresh2 =
            Arc::new(MasterShard::new(0, spec(), None, 1, Arc::new(clock.clone())).unwrap());
        let got = sched.recover_shard(&fresh2).unwrap();
        assert_eq!(got, 5);
        assert_eq!(fresh2.snapshot(), masters[0].snapshot());
        std::fs::remove_dir_all(base).ok();
    }

    #[test]
    fn slot_map_stewardship_publishes_and_plans() {
        use crate::reshard::SlotMap;
        let (sched, _, _, base) = setup(60_000);
        assert!(sched.load_slot_map().is_none());
        let m0 = SlotMap::uniform(64, 3);
        sched.publish_slot_map(&m0).unwrap();
        assert_eq!(sched.load_slot_map().unwrap(), m0);
        // Plan a shrink to 2 shards, apply, publish the bumped epoch.
        let moves = sched.plan_rebalance(&m0, 2);
        assert!(!moves.is_empty());
        let m1 = m0.rebalanced(&moves).unwrap();
        sched.publish_slot_map(&m1).unwrap();
        assert_eq!(sched.load_slot_map().unwrap().epoch, 1);
        // Rollback to the stale epoch is rejected.
        assert!(sched.publish_slot_map(&m0).is_err());
        std::fs::remove_dir_all(base).ok();
    }

    #[test]
    fn version_counter_resumes_after_restart() {
        let (sched, masters, clock, base) = setup(60_000);
        push_some(&masters, 1);
        sched.checkpoint_now(&masters, vec![], 0.5).unwrap();
        sched.checkpoint_now(&masters, vec![], 0.5).unwrap();
        // "Restart" the scheduler against the same store.
        let sched2 = Scheduler::new(
            MetaStore::new(Arc::new(clock.clone())),
            sched.store.clone(),
            "ctr",
            CkptPolicy::default(),
            Arc::new(clock.clone()),
        );
        let v3 = sched2.checkpoint_now(&masters, vec![], 0.5).unwrap();
        assert_eq!(v3, 3);
        std::fs::remove_dir_all(base).ok();
    }
}
