//! Model metrics monitoring via progressive validation (§4.3.1).
//!
//! The paper's trick: "WeiPS uses the predicted result of the training
//! samples as the estimated result of the current model parameters, this
//! happens before the training sample data update gradients." The trainer
//! therefore feeds every batch's *pre-update* predictions here — fresh
//! evaluation data, with no samples withheld from training.
//!
//! Metrics: streaming AUC (fixed-bin rank estimator), logloss and CTR
//! calibration, in both cumulative and sliding-window form; the sliding
//! window is what the downgrade trigger watches (§4.3.2a: the smoothed
//! threshold compares windowed metric levels, not single points).

use std::collections::VecDeque;
use std::sync::Mutex;

const BINS: usize = 1024;

/// Fixed-bin streaming AUC estimator: O(1) update, O(bins) read.
#[derive(Debug, Clone)]
pub struct StreamingAuc {
    pos: Vec<u64>,
    neg: Vec<u64>,
    n_pos: u64,
    n_neg: u64,
}

impl Default for StreamingAuc {
    fn default() -> Self {
        Self::new()
    }
}

impl StreamingAuc {
    /// Empty estimator.
    pub fn new() -> StreamingAuc {
        StreamingAuc { pos: vec![0; BINS], neg: vec![0; BINS], n_pos: 0, n_neg: 0 }
    }

    /// Record one (prediction in [0,1], binary label) pair.
    pub fn add(&mut self, pred: f32, label: f32) {
        let bin = ((pred.clamp(0.0, 1.0) * (BINS - 1) as f32) as usize).min(BINS - 1);
        if label > 0.5 {
            self.pos[bin] += 1;
            self.n_pos += 1;
        } else {
            self.neg[bin] += 1;
            self.n_neg += 1;
        }
    }

    /// Samples observed.
    pub fn count(&self) -> u64 {
        self.n_pos + self.n_neg
    }

    /// AUC estimate (0.5 when degenerate).
    pub fn auc(&self) -> f64 {
        if self.n_pos == 0 || self.n_neg == 0 {
            return 0.5;
        }
        // P(score_pos > score_neg) + 0.5 P(equal), via bin sweep.
        let mut neg_below = 0u64;
        let mut auc_sum = 0.0f64;
        for b in 0..BINS {
            let p = self.pos[b] as f64;
            let n = self.neg[b] as f64;
            auc_sum += p * (neg_below as f64 + n / 2.0);
            neg_below += self.neg[b];
        }
        auc_sum / (self.n_pos as f64 * self.n_neg as f64)
    }

    /// Merge another estimator into this one.
    pub fn merge(&mut self, other: &StreamingAuc) {
        for b in 0..BINS {
            self.pos[b] += other.pos[b];
            self.neg[b] += other.neg[b];
        }
        self.n_pos += other.n_pos;
        self.n_neg += other.n_neg;
    }

    /// Reset to empty.
    pub fn clear(&mut self) {
        self.pos.iter_mut().for_each(|x| *x = 0);
        self.neg.iter_mut().for_each(|x| *x = 0);
        self.n_pos = 0;
        self.n_neg = 0;
    }
}

/// A point-in-time metrics snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct MonitorSnapshot {
    pub samples: u64,
    /// Cumulative AUC since start.
    pub auc: f64,
    /// Sliding-window AUC (the downgrade trigger input).
    pub window_auc: f64,
    /// Cumulative mean logloss.
    pub logloss: f64,
    /// Mean prediction / mean label (1.0 = perfectly calibrated).
    pub calibration: f64,
}

struct MonitorState {
    cumulative: StreamingAuc,
    window: VecDeque<StreamingAuc>,
    window_chunk: StreamingAuc,
    chunk_size: u64,
    max_chunks: usize,
    loss_sum: f64,
    pred_sum: f64,
    label_sum: f64,
    samples: u64,
}

/// Progressive-validation monitor. Thread-safe; one per model.
pub struct Monitor {
    state: Mutex<MonitorState>,
}

impl Monitor {
    /// `window_samples` ≈ sliding window size (rounded to 8 chunks).
    pub fn new(window_samples: u64) -> Monitor {
        let max_chunks = 8;
        Monitor {
            state: Mutex::new(MonitorState {
                cumulative: StreamingAuc::new(),
                window: VecDeque::new(),
                window_chunk: StreamingAuc::new(),
                chunk_size: (window_samples / max_chunks as u64).max(1),
                max_chunks,
                loss_sum: 0.0,
                pred_sum: 0.0,
                label_sum: 0.0,
                samples: 0,
            }),
        }
    }

    /// Feed one batch of pre-update predictions + labels.
    pub fn observe_batch(&self, preds: &[f32], labels: &[f32]) {
        debug_assert_eq!(preds.len(), labels.len());
        let mut s = self.state.lock().unwrap();
        for (&p, &y) in preds.iter().zip(labels) {
            let p64 = (p as f64).clamp(1e-7, 1.0 - 1e-7);
            s.loss_sum -= if y > 0.5 { p64.ln() } else { (1.0 - p64).ln() };
            s.pred_sum += p as f64;
            s.label_sum += y as f64;
            s.samples += 1;
            s.cumulative.add(p, y);
            s.window_chunk.add(p, y);
            if s.window_chunk.count() >= s.chunk_size {
                let full = std::mem::take(&mut s.window_chunk);
                s.window.push_back(full);
                if s.window.len() > s.max_chunks {
                    s.window.pop_front();
                }
            }
        }
    }

    /// Register the progressive-validation gauges (`weips_model_*`) under
    /// `role` on the global metrics registry. Each sampler takes one
    /// [`Monitor::snapshot`] at scrape time and holds only a `Weak`, so a
    /// dropped monitor's series disappear from scrapes.
    pub fn register_metrics(self: &std::sync::Arc<Self>, role: &str) {
        let gauges: [(&'static str, fn(&MonitorSnapshot) -> f64); 5] = [
            ("weips_model_auc", |s| s.auc),
            ("weips_model_window_auc", |s| s.window_auc),
            ("weips_model_logloss", |s| s.logloss),
            ("weips_model_calibration", |s| s.calibration),
            ("weips_model_samples", |s| s.samples as f64),
        ];
        for (name, get) in gauges {
            let weak = std::sync::Arc::downgrade(self);
            crate::metrics::register_fn(
                name,
                &[("role", role.to_string())],
                Box::new(move || weak.upgrade().map(|m| get(&m.snapshot()))),
            );
        }
        // The window AUC also feeds the `window_auc_low` alert rule. A
        // cold monitor (no samples) reports nothing so a critical alert
        // can't fire at startup, matching the domino's samples > 0 guard.
        let weak = std::sync::Arc::downgrade(self);
        crate::alerts::register_source(
            "model_window_auc",
            format!("role={role}"),
            Box::new(move || {
                weak.upgrade().and_then(|m| {
                    let s = m.snapshot();
                    (s.samples > 0).then_some(s.window_auc)
                })
            }),
        );
    }

    /// Current metrics.
    pub fn snapshot(&self) -> MonitorSnapshot {
        let s = self.state.lock().unwrap();
        let mut win = StreamingAuc::new();
        for chunk in &s.window {
            win.merge(chunk);
        }
        win.merge(&s.window_chunk);
        MonitorSnapshot {
            samples: s.samples,
            auc: s.cumulative.auc(),
            window_auc: win.auc(),
            logloss: if s.samples == 0 { 0.0 } else { s.loss_sum / s.samples as f64 },
            calibration: if s.label_sum == 0.0 { 1.0 } else { s.pred_sum / s.label_sum },
        }
    }
}

// ---------------------------------------------------------------------------
// Downgrade triggers (§4.3.2a)
// ---------------------------------------------------------------------------

/// A trigger decides, metric point by metric point, whether the model has
/// degraded enough to roll back.
pub trait Trigger: Send {
    /// Feed one metric observation (higher = better, e.g. window AUC);
    /// returns true when a downgrade should fire.
    fn observe(&mut self, value: f64) -> bool;
}

/// Naive threshold: fire the moment the metric dips below `threshold`.
/// Kept as the baseline the paper criticizes ("this may occur false
/// alarms in action") — E5 quantifies the false-alarm rate.
pub struct PlainThreshold {
    pub threshold: f64,
}

impl Trigger for PlainThreshold {
    fn observe(&mut self, value: f64) -> bool {
        value < self.threshold
    }
}

/// Smoothed threshold (§4.3.2a): "a smoothing threshold strategy that
/// sample a few more contrast points can be used, and the threshold after
/// smoothing can better catch the true change of the data distribution."
/// Fires only when the mean of the last `smooth_k` points is below
/// `threshold` AND each of those points individually dipped.
pub struct SmoothedThreshold {
    pub threshold: f64,
    pub smooth_k: usize,
    recent: VecDeque<f64>,
}

impl SmoothedThreshold {
    /// New trigger over `smooth_k` contrast points.
    pub fn new(threshold: f64, smooth_k: usize) -> SmoothedThreshold {
        SmoothedThreshold { threshold, smooth_k: smooth_k.max(1), recent: VecDeque::new() }
    }
}

impl Trigger for SmoothedThreshold {
    fn observe(&mut self, value: f64) -> bool {
        self.recent.push_back(value);
        if self.recent.len() > self.smooth_k {
            self.recent.pop_front();
        }
        if self.recent.len() < self.smooth_k {
            return false;
        }
        let mean: f64 = self.recent.iter().sum::<f64>() / self.recent.len() as f64;
        mean < self.threshold && self.recent.iter().all(|v| *v < self.threshold)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn auc_perfect_and_random() {
        let mut a = StreamingAuc::new();
        for i in 0..500 {
            a.add(0.9 + (i % 10) as f32 * 0.01, 1.0);
            a.add(0.1 - (i % 10) as f32 * 0.01, 0.0);
        }
        assert!(a.auc() > 0.99, "{}", a.auc());

        let mut r = StreamingAuc::new();
        let mut rng = Rng::new(1);
        for _ in 0..20_000 {
            r.add(rng.gen_f32(), if rng.gen_bool(0.5) { 1.0 } else { 0.0 });
        }
        assert!((r.auc() - 0.5).abs() < 0.02, "{}", r.auc());
    }

    #[test]
    fn auc_degenerate_cases() {
        let a = StreamingAuc::new();
        assert_eq!(a.auc(), 0.5);
        let mut only_pos = StreamingAuc::new();
        only_pos.add(0.8, 1.0);
        assert_eq!(only_pos.auc(), 0.5);
    }

    #[test]
    fn auc_matches_exact_computation() {
        // Compare against the O(n^2) pairwise definition on a small set.
        let preds = [0.1f32, 0.4, 0.35, 0.8, 0.65, 0.2, 0.9, 0.5];
        let labels = [0.0f32, 0.0, 1.0, 1.0, 1.0, 0.0, 1.0, 0.0];
        let mut a = StreamingAuc::new();
        for (p, y) in preds.iter().zip(&labels) {
            a.add(*p, *y);
        }
        let mut num = 0.0;
        let mut den = 0.0;
        for i in 0..preds.len() {
            for j in 0..preds.len() {
                if labels[i] > 0.5 && labels[j] < 0.5 {
                    den += 1.0;
                    if preds[i] > preds[j] {
                        num += 1.0;
                    } else if preds[i] == preds[j] {
                        num += 0.5;
                    }
                }
            }
        }
        assert!((a.auc() - num / den).abs() < 0.01, "{} vs {}", a.auc(), num / den);
    }

    #[test]
    fn monitor_tracks_quality_shift() {
        // Good predictions, then inverted ones: window AUC collapses while
        // cumulative AUC degrades slowly — exactly why the trigger watches
        // the window.
        let m = Monitor::new(1_000);
        let mut rng = Rng::new(7);
        for _ in 0..3_000 {
            let y = rng.gen_bool(0.5);
            let p = if y { 0.6 + 0.3 * rng.gen_f32() } else { 0.1 + 0.3 * rng.gen_f32() };
            m.observe_batch(&[p], &[y as u8 as f32]);
        }
        let good = m.snapshot();
        assert!(good.auc > 0.9 && good.window_auc > 0.9);
        for _ in 0..1_500 {
            let y = rng.gen_bool(0.5);
            let p = if y { 0.1 + 0.3 * rng.gen_f32() } else { 0.6 + 0.3 * rng.gen_f32() };
            m.observe_batch(&[p], &[y as u8 as f32]);
        }
        let bad = m.snapshot();
        assert!(bad.window_auc < 0.2, "window {}", bad.window_auc);
        assert!(bad.auc > bad.window_auc, "cumulative lags the window");
        assert!(bad.logloss > good.logloss);
    }

    #[test]
    fn calibration_detects_bias() {
        let m = Monitor::new(100);
        // Predict 0.8 when the true rate is 0.4 -> calibration ~2.
        let mut rng = Rng::new(3);
        for _ in 0..2_000 {
            m.observe_batch(&[0.8], &[rng.gen_bool(0.4) as u8 as f32]);
        }
        let snap = m.snapshot();
        assert!((snap.calibration - 2.0).abs() < 0.3, "{}", snap.calibration);
    }

    #[test]
    fn plain_trigger_fires_on_single_dip() {
        let mut t = PlainThreshold { threshold: 0.7 };
        assert!(!t.observe(0.75));
        assert!(t.observe(0.69)); // one noisy point = false alarm
    }

    #[test]
    fn smoothed_trigger_ignores_noise_catches_shift() {
        let mut t = SmoothedThreshold::new(0.7, 3);
        // Noisy single dips never fire.
        for v in [0.75, 0.65, 0.75, 0.64, 0.78, 0.66, 0.8] {
            assert!(!t.observe(v), "fired on noise at {v}");
        }
        // Sustained degradation fires within k points.
        assert!(!t.observe(0.6));
        assert!(!t.observe(0.58));
        assert!(t.observe(0.55));
    }

    #[test]
    fn smoothed_trigger_needs_k_points() {
        let mut t = SmoothedThreshold::new(0.7, 5);
        for _ in 0..4 {
            assert!(!t.observe(0.1)); // not enough contrast points yet
        }
        assert!(t.observe(0.1));
    }

    #[test]
    fn plain_trigger_never_fires_on_nan() {
        // NaN compares false against any threshold: a poisoned metric
        // must not roll the model back.
        let mut t = PlainThreshold { threshold: 0.7 };
        assert!(!t.observe(f64::NAN));
        assert!(t.observe(0.1), "recovers after the NaN point");
    }

    #[test]
    fn smoothed_trigger_suppresses_nan_windows() {
        let mut t = SmoothedThreshold::new(0.7, 3);
        // A NaN inside the window poisons both the mean and the all-dip
        // check to false — no fire until k clean dips follow it.
        assert!(!t.observe(0.1));
        assert!(!t.observe(f64::NAN));
        assert!(!t.observe(0.1), "NaN poisons the window mean");
        assert!(!t.observe(0.1), "NaN still inside the k=3 window");
        // NaN has rolled out: [0.1, 0.1, 0.1] is the first clean window.
        assert!(t.observe(0.1));
    }

    #[test]
    fn smoothed_trigger_clamps_zero_k_to_one() {
        // smooth_k = 0 would make every window "complete" vacuously;
        // the constructor clamps it to 1 (plain-threshold behavior).
        let mut t = SmoothedThreshold::new(0.7, 0);
        assert_eq!(t.smooth_k, 1);
        assert!(!t.observe(0.8));
        assert!(t.observe(0.6));
    }

    #[test]
    fn smoothed_trigger_mean_guard_blocks_mixed_windows() {
        // Every point below threshold is required, not just the mean:
        // one recovered point inside the window vetoes the fire.
        let mut t = SmoothedThreshold::new(0.7, 3);
        assert!(!t.observe(0.1));
        assert!(!t.observe(0.1));
        assert!(!t.observe(0.9), "window mean 0.36 < 0.7 but 0.9 recovered");
        assert!(!t.observe(0.1), "0.9 still in window");
        assert!(!t.observe(0.1), "0.9 still in window");
        assert!(t.observe(0.1), "three consecutive dips fire");
    }
}
