//! Coordinator: assembles a full WeiPS deployment.
//!
//! [`LocalCluster`] wires every role of Figure 2 — master shards with
//! their gather→pusher sync pipelines, slave replica groups with scatter
//! consumers, the scheduler, the monitor, the domino downgrade — inside
//! one process. Components talk through the same [`Channel`] RPC facade
//! used in distributed mode, so examples, benches and integration tests
//! exercise the production code paths; the `weips` CLI launches the same
//! pieces across processes over TCP.
//!
//! The cluster is **tick-driven**: `train_step` / `sync_tick` /
//! `control_tick` advance it deterministically (benches measure exact
//! work), and `start_pumps` spawns background threads for wall-clock
//! operation (examples, CLI).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::config::{CkptMode, ClusterConfig, ModelSpec};
use crate::downgrade::{Domino, DowngradePlan, SwitchStrategy, VersionManager};
use crate::meta::MetaStore;
use crate::monitor::{Monitor, SmoothedThreshold};
use crate::net::Channel;
use crate::optim::Optimizer;
use crate::queue::{Queue, Topic, WalLog};
use crate::replica::{BalancePolicy, ReplicaGroup};
use crate::reshard::{MigrationOpts, MigrationReport, SlotTransfer};
use crate::runtime::Engine;
use crate::sample::{Workload, WorkloadConfig};
use crate::scheduler::{CkptPolicy, Scheduler};
use crate::server::master::{MasterService, MasterShard};
use crate::server::slave::{SlaveService, SlaveShard};
use crate::storage::incremental::{self, IncrPolicy, WalJournal};
use crate::storage::{CheckpointStore, ChunkData, CkptKind};
use crate::sync::{Gather, Pusher, Router, Scatter, ServingWeights};
use crate::util::clock::{Clock, SystemClock};
use crate::util::ThreadPool;
use crate::worker::{Predictor, ShardedClient, SlaveClient, SlaveEndpoint, Trainer};
use crate::{Error, Result};

/// Options beyond the cluster config.
pub struct ClusterOpts {
    pub cluster: ClusterConfig,
    pub artifacts_dir: std::path::PathBuf,
    /// Checkpoint root (temp dir when None).
    pub data_dir: Option<std::path::PathBuf>,
    pub workload: WorkloadConfig,
    /// Downgrade trigger: window-AUC threshold + smoothing points.
    pub trigger_threshold: f64,
    pub trigger_smooth: usize,
    pub switch_strategy: SwitchStrategy,
}

impl Default for ClusterOpts {
    fn default() -> Self {
        ClusterOpts {
            cluster: ClusterConfig::default(),
            artifacts_dir: crate::runtime::default_artifacts_dir(),
            data_dir: None,
            workload: WorkloadConfig::default(),
            trigger_threshold: 0.55,
            trigger_smooth: 3,
            switch_strategy: SwitchStrategy::LatestStable,
        }
    }
}

/// A fully wired in-process WeiPS cluster.
pub struct LocalCluster {
    pub engine: Arc<Engine>,
    pub spec: ModelSpec,
    pub cfg: ClusterConfig,
    pub queue: Arc<Queue>,
    pub topic: Arc<Topic>,
    pub meta: MetaStore,
    pub store: Arc<CheckpointStore>,
    /// Per-shard write-ahead log: every sync tick journals each master's
    /// dirty window as a micro-delta chunk, bounding the data loss
    /// between sealed checkpoint deltas to one tick.
    pub wal: Arc<WalLog>,
    journals: Vec<Mutex<WalJournal>>,
    pub scheduler: Scheduler,
    /// Master-cluster slot router: one shared cell across trainer
    /// clients, shard route guards and the migration driver, so a single
    /// epoch install cuts everything over ([`Self::migrate_slots`]).
    pub master_router: Router,
    pub masters: Vec<Arc<MasterShard>>,
    gathers: Vec<Mutex<Gather>>,
    pushers: Vec<Pusher>,
    /// `slaves[shard][replica]`
    pub slaves: Vec<Vec<Arc<SlaveShard>>>,
    scatters: Vec<Vec<Mutex<Scatter>>>,
    pub groups: Vec<Arc<ReplicaGroup<SlaveEndpoint>>>,
    /// Shared pool driving parallel gather snapshots, scatter applies and
    /// expire passes across every shard (`None` when `sync_threads = 0`).
    pub sync_pool: Option<Arc<ThreadPool>>,
    pub monitor: Arc<Monitor>,
    pub vm: VersionManager,
    pub domino: Mutex<Domino>,
    pub trainer: Trainer,
    pub predictor: Predictor,
    /// Predictor-side hot-id cache; scatter taps keep it coherent.
    /// Exposed for the serving bench/tests (hit-rate and stats probes).
    pub serving_cache: Arc<crate::worker::HotIdCache>,
    workload: Mutex<Workload>,
    clock: Arc<dyn Clock>,
    data_dir: std::path::PathBuf,
    owns_data_dir: bool,
    pumps_running: Arc<AtomicBool>,
    pump_handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
    pub sim_time_ms: std::sync::atomic::AtomicU64,
}

/// (model, table, shard, seq, created_ms) of a sampled batch in a sync
/// tick — the envelope context needed to attribute the tick's WAL append
/// to the batch's update-journey trace.
type SampledMeta = (String, String, u32, u64, u64);

fn collect_sampled(batches: &[crate::proto::SyncBatch], out: &mut Vec<SampledMeta>) {
    for b in batches {
        if crate::trace::sampled(b.seq) {
            out.push((b.model.clone(), b.table.clone(), b.shard, b.seq, b.created_ms));
        }
    }
}

/// The WAL journals the whole tick's dirty windows in one pass, so the
/// tick-level append timing is attributed to every sampled batch pushed
/// this tick.
fn record_wal_spans(sampled: &[SampledMeta], start_ns: u64, dur_ns: u64) {
    for (model, table, shard, seq, created_ms) in sampled {
        crate::trace::record_stage(
            crate::trace::trace_id(model, table, *shard, *seq),
            "wal_append",
            "master",
            format!("shard={shard}"),
            start_ns,
            dur_ns,
            *created_ms,
            *seq,
            *shard,
        );
    }
}

impl LocalCluster {
    /// Build and wire the whole cluster.
    pub fn new(opts: ClusterOpts) -> Result<LocalCluster> {
        let engine = Arc::new(Engine::load(&opts.artifacts_dir)?);
        let cfg = opts.cluster.clone();
        let spec = ModelSpec::derive(&cfg.model_name, cfg.model_kind, engine.config());
        let clock: Arc<dyn Clock> = Arc::new(SystemClock);
        // Update-journey tracing + readiness bounds are process-global
        // (the trace sink and health registry are), configured from the
        // cluster knobs at bring-up.
        crate::trace::configure(cfg.trace_sample_every);
        crate::metrics::set_health_bound(
            "scatter_lag_records",
            Some(cfg.health_scatter_lag_max as f64),
        );
        crate::metrics::set_health_bound(
            "wal_unsynced_appends",
            Some(cfg.health_wal_unsynced_max as f64),
        );
        // The alert evaluator's AUC rule and the domino trigger read the
        // same knob: a firing `window_auc_low` is the declared face of the
        // quality dip the domino acts on.
        crate::alerts::set_rule_bound("window_auc_low", Some(opts.trigger_threshold));

        let (data_dir, owns_data_dir) = match opts.data_dir {
            Some(d) => (d, false),
            None => {
                let d = std::env::temp_dir().join(format!(
                    "weips-cluster-{}-{:x}",
                    std::process::id(),
                    crate::util::mono_ns()
                ));
                (d, true)
            }
        };
        let mut store = CheckpointStore::new(
            data_dir.join("ckpt-local"),
            Some(data_dir.join("ckpt-remote")),
        );
        store.set_mmap_load(cfg.ckpt_mmap_load);
        let store = Arc::new(store);
        store.register_metrics("master");
        let wal = Arc::new(WalLog::open_with(
            data_dir.join("wal"),
            cfg.master_shards as usize,
            cfg.wal_sync_every,
        )?);
        let journals: Vec<Mutex<WalJournal>> =
            (0..cfg.master_shards).map(|i| Mutex::new(WalJournal::new(i))).collect();
        let meta = MetaStore::new(clock.clone());
        let queue = Arc::new(Queue::default());
        let topic = queue.create_topic(
            &format!("sync.{}", cfg.model_name),
            cfg.queue_partitions as usize,
        )?;

        // -- masters + sync pipeline -----------------------------------------
        // One pool shared by every gather/scatter/expire in the process:
        // the sync stages parallelize across table stripes without each
        // shard paying for its own thread fleet.
        let sync_pool = cfg.sync_pool();
        let master_router = Router::with_slots(cfg.master_shards, cfg.reshard_slots as usize);
        let mut masters = Vec::new();
        let mut gathers = Vec::new();
        let mut pushers = Vec::new();
        for i in 0..cfg.master_shards {
            let m = Arc::new(MasterShard::with_row_store(
                i,
                spec.clone(),
                Some(engine.clone()),
                cfg.entry_threshold,
                cfg.table_stripes as usize,
                cfg.table_row_store,
                clock.clone(),
            )?);
            // Slot-route guard: stale-epoch pushes NACK back to the
            // client's re-route loop instead of landing on the wrong
            // shard during a live migration.
            m.set_route_guard(master_router.clone());
            // Full mode has no delta consumer: skip tombstone tracking so
            // expired rows free all their memory.
            if cfg.ckpt_mode == CkptMode::Full {
                m.set_incremental_tracking(false);
            }
            gathers.push(Mutex::new(Gather::with_pool(
                m.clone(),
                cfg.gather_mode,
                clock.clone(),
                sync_pool.clone(),
            )));
            pushers.push(Pusher::new(topic.clone(), i));
            masters.push(m);
        }

        // -- slaves + scatter + replica groups --------------------------------
        let serving_tables: Vec<(String, usize)> =
            spec.sparse.iter().map(|t| (t.name.clone(), t.dim)).collect();
        let dense_tables: Vec<(String, usize)> =
            spec.dense.iter().map(|d| (d.name.clone(), d.len)).collect();
        let transform_tables: Vec<(String, Arc<dyn Optimizer>, usize)> = spec
            .sparse
            .iter()
            .map(|t| Ok((t.name.clone(), spec.optimizer_for(&t.name)?, t.dim)))
            .collect::<Result<Vec<_>>>()?;
        let slave_router = Router::with_slots(cfg.slave_shards, cfg.reshard_slots as usize);

        let mut slaves = Vec::new();
        let mut scatters = Vec::new();
        let mut groups = Vec::new();
        // Serving hot-id cache, invalidated by the scatter taps below
        // (capacity 0 disables caching without touching the read path).
        let serving_cache = crate::worker::HotIdCache::new(cfg.serving_cache_rows);
        for s in 0..cfg.slave_shards {
            let mut replicas = Vec::new();
            let mut shard_scatters = Vec::new();
            let mut endpoints = Vec::new();
            for r in 0..cfg.slave_replicas {
                let shard = Arc::new(SlaveShard::with_stripes(
                    s,
                    r,
                    &cfg.model_name,
                    serving_tables.clone(),
                    dense_tables.clone(),
                    Arc::new(ServingWeights::new(transform_tables.clone())),
                    slave_router.clone(),
                    cfg.table_stripes as usize,
                ));
                // Large predict pulls prefetch their stripes on the
                // shared sync pool.
                shard.set_sync_pool(sync_pool.clone());
                let mut scatter = Scatter::with_pool(
                    topic.clone(),
                    shard.clone(),
                    cfg.master_shards,
                    cfg.slave_shards,
                    clock.clone(),
                    sync_pool.clone(),
                );
                // Every replica's apply invalidates the serving cache:
                // the predictor may refill from any replica, so a
                // cached row is only trustworthy once the *last* apply
                // of the tick has stamped its stripe.
                scatter.add_tap(serving_cache.clone());
                shard_scatters.push(Mutex::new(scatter));
                let ch = Channel::local(Arc::new(SlaveService { shard: shard.clone() }));
                endpoints.push(Arc::new(SlaveEndpoint::local(ch, shard.clone())));
                replicas.push(shard);
            }
            groups.push(Arc::new(ReplicaGroup::new(endpoints, cfg.replica_balance)));
            slaves.push(replicas);
            scatters.push(shard_scatters);
        }

        // -- workers ------------------------------------------------------------
        let master_channels: Vec<Channel> = masters
            .iter()
            .map(|m| {
                Channel::local(Arc::new(MasterService {
                    shard: m.clone(),
                    store: Some(store.clone()),
                }))
            })
            .collect();
        let monitor = Arc::new(Monitor::new(4 * spec.batch_train as u64 * 8));
        let trainer = Trainer::new(
            engine.clone(),
            spec.clone(),
            ShardedClient::with_router(&cfg.model_name, master_channels, master_router.clone()),
            monitor.clone(),
        );
        // Same universe as the slave shards' router — a predictor
        // with a different `reshard_slots` would route pulls to
        // shards that never held the ids.
        let mut slave_client =
            SlaveClient::with_router(&cfg.model_name, groups.clone(), slave_router.clone());
        slave_client.set_cache(serving_cache.clone());
        slave_client.register_metrics("predictor");
        let predictor = Predictor::new(engine.clone(), spec.clone(), slave_client);

        // -- control plane --------------------------------------------------------
        let mut scheduler = Scheduler::new(
            meta.clone(),
            store.clone(),
            &cfg.model_name,
            CkptPolicy {
                interval_ms: cfg.ckpt_interval_ms,
                jitter: 0.3,
                keep_local: cfg.ckpt_keep,
                remote_every: cfg.remote_every,
            },
            clock.clone(),
        );
        scheduler.set_incr_policy(IncrPolicy {
            base_every: cfg.ckpt_base_every.max(1),
            keep_chains: cfg.ckpt_keep.max(1),
        });
        let vm = VersionManager::new(&cfg.model_name, 0);
        // Cooldown must outlast the monitor window (in control ticks ≈
        // batches) or post-rollback contamination re-fires the domino and
        // needlessly quarantines the healthy target.
        let domino = Mutex::new(Domino::new(
            Box::new(SmoothedThreshold::new(opts.trigger_threshold, opts.trigger_smooth)),
            opts.switch_strategy,
            48,
        ));
        let workload = Mutex::new(Workload::new(WorkloadConfig {
            fields: spec.fields,
            ..opts.workload
        }));

        // -- observability -----------------------------------------------------
        // Seal the live slot map into every checkpoint manifest (cold-start
        // routing recovery, see `recover_routing`) and register every
        // component's series with the process-global metrics registry. All
        // samplers hold Weak refs: tearing the cluster down removes its
        // series from the next scrape.
        scheduler.set_route_source(master_router.clone());
        for m in &masters {
            m.register_metrics("master");
        }
        for replicas in &slaves {
            for s in replicas {
                s.register_metrics("slave");
            }
        }
        monitor.register_metrics("trainer");
        master_router.register_metrics("master");
        for p in 0..topic.partition_count() {
            let weak = Arc::downgrade(&topic);
            crate::metrics::register_fn(
                "weips_queue_depth_records",
                &[("role", "broker".to_string()), ("partition", p.to_string())],
                Box::new(move || {
                    weak.upgrade()
                        .map(|t| t.partition(p).map(|part| part.len() as f64).unwrap_or(0.0))
                }),
            );
        }

        Ok(LocalCluster {
            engine,
            spec,
            cfg,
            queue,
            topic,
            meta,
            store,
            wal,
            journals,
            scheduler,
            master_router,
            masters,
            gathers,
            pushers,
            slaves,
            scatters,
            groups,
            sync_pool,
            monitor,
            vm,
            domino,
            trainer,
            predictor,
            serving_cache,
            workload,
            clock,
            data_dir,
            owns_data_dir,
            pumps_running: Arc::new(AtomicBool::new(false)),
            pump_handles: Mutex::new(Vec::new()),
            sim_time_ms: std::sync::atomic::AtomicU64::new(0),
        })
    }

    /// Simulated workload timestamp, advanced `ms_per_step` per batch.
    fn next_sim_time(&self, ms: u64) -> u64 {
        self.sim_time_ms.fetch_add(ms, Ordering::Relaxed)
    }

    /// Run one training step on a fresh synthetic batch; returns the loss.
    pub fn train_step(&self) -> Result<f32> {
        let t = self.next_sim_time(100);
        let samples = {
            let mut w = self.workload.lock().unwrap();
            w.batch(t, self.spec.batch_train)
        };
        Ok(self.trainer.train_batch(&samples)?.loss)
    }

    /// Drive the sync pipeline once: gather + push on every master,
    /// journal each master's dirty window to the WAL, then scatter on
    /// every slave replica. Returns (batches pushed, applied).
    pub fn sync_tick(&self) -> Result<(usize, usize)> {
        let tracing = crate::trace::enabled();
        let mut sampled = Vec::new();
        let mut pushed = 0;
        for (i, g) in self.gathers.iter().enumerate() {
            // Hold the gather lock across the push: concurrent flushers
            // (wall-clock pumps, the migration hand-off) must not be able
            // to interleave a newer window into the partition before an
            // already-polled older one.
            let mut g = g.lock().unwrap();
            let batches = g.poll();
            pushed += batches.len();
            if tracing {
                collect_sampled(&batches, &mut sampled);
            }
            self.pushers[i].push_all(&batches)?;
        }
        let wal_start = if tracing { crate::util::mono_ns() } else { 0 };
        self.journal_wal()?;
        if !sampled.is_empty() {
            let wal_ns = crate::util::mono_ns().saturating_sub(wal_start);
            record_wal_spans(&sampled, wal_start, wal_ns);
        }
        let mut applied = 0;
        for shard in &self.scatters {
            for sc in shard {
                applied += sc.lock().unwrap().poll(Duration::ZERO)?;
            }
        }
        Ok((pushed, applied))
    }

    /// Journal every master's dirty window as a WAL micro-delta (no-op
    /// in full checkpoint mode and for clean windows). The micro-delta
    /// encodes fan out across the sync pool; appends stay sequential in
    /// shard order so per-partition offsets match a sequential tick.
    fn journal_wal(&self) -> Result<()> {
        if self.cfg.ckpt_mode != CkptMode::Incremental {
            return Ok(());
        }
        let now = self.clock.now_ms();
        incremental::journal_tick(
            &self.journals,
            &self.masters,
            &self.wal,
            now,
            self.sync_pool.as_deref(),
        )?;
        Ok(())
    }

    /// Force every pending update through the pipeline until slaves are
    /// fully caught up.
    pub fn flush_sync(&self) -> Result<()> {
        let tracing = crate::trace::enabled();
        let mut sampled = Vec::new();
        for (i, g) in self.gathers.iter().enumerate() {
            let mut g = g.lock().unwrap();
            let batches = g.flush_now();
            if tracing {
                collect_sampled(&batches, &mut sampled);
            }
            self.pushers[i].push_all(&batches)?;
        }
        let wal_start = if tracing { crate::util::mono_ns() } else { 0 };
        self.journal_wal()?;
        if !sampled.is_empty() {
            let wal_ns = crate::util::mono_ns().saturating_sub(wal_start);
            record_wal_spans(&sampled, wal_start, wal_ns);
        }
        loop {
            let mut lag = 0;
            for shard in &self.scatters {
                for sc in shard {
                    let mut sc = sc.lock().unwrap();
                    sc.poll(Duration::ZERO)?;
                    lag += sc.lag();
                }
            }
            if lag == 0 {
                return Ok(());
            }
        }
    }

    /// Total scatter lag across replicas (records).
    pub fn sync_lag(&self) -> u64 {
        self.scatters
            .iter()
            .flat_map(|s| s.iter())
            .map(|sc| sc.lock().unwrap().lag())
            .sum()
    }

    /// Serve predictions for raw feature-id requests via slave replicas.
    pub fn predict(&self, requests: &[Vec<u64>]) -> Result<Vec<f32>> {
        self.predictor.predict(requests)
    }

    /// Generate `n` serving requests from the same workload distribution.
    pub fn serving_requests(&self, n: usize) -> Vec<Vec<u64>> {
        let t = self.sim_time_ms.load(Ordering::Relaxed);
        let mut w = self.workload.lock().unwrap();
        w.batch(t, n).into_iter().map(|s| s.ids).collect()
    }

    /// Current queue offsets per partition (recorded into checkpoints).
    pub fn queue_offsets(&self) -> Vec<u64> {
        (0..self.topic.partition_count())
            .map(|p| self.topic.partition(p).map(|x| x.latest_offset()).unwrap_or(0))
            .collect()
    }

    /// Take a cluster checkpoint now; returns the version. In incremental
    /// mode this seals a base or delta chunk per the chain policy,
    /// re-arms the WAL journals and trims the WAL below the seal.
    pub fn checkpoint(&self) -> Result<u64> {
        let metric = self.monitor.snapshot().window_auc;
        let v = match self.cfg.ckpt_mode {
            CkptMode::Full => {
                self.scheduler.checkpoint_now(&self.masters, self.queue_offsets(), metric)?
            }
            CkptMode::Incremental => {
                let wal_offsets = self.wal.latest_offsets();
                let (v, _kind, cuts) = self.scheduler.checkpoint_incremental(
                    &self.masters,
                    self.queue_offsets(),
                    wal_offsets.clone(),
                    metric,
                )?;
                // Journals only need to cover what the sealed chunks do
                // not; the WAL below the seal is covered by the chain.
                for (i, m) in self.masters.iter().enumerate() {
                    self.journals[i].lock().unwrap().reset(cuts[i], m.dense_versions());
                }
                for (p, off) in wal_offsets.iter().enumerate() {
                    self.wal.trim_until(p as u32, *off)?;
                }
                v
            }
        };
        self.vm.advance(v);
        for shard in &self.slaves {
            for replica in shard {
                replica.set_version(v);
            }
        }
        Ok(v)
    }

    /// Load the chunk lineage for one master shard at `version`: the base
    /// snapshot first, then each delta chunk (a pre-incremental full
    /// checkpoint is a chain of one). Slave bootstrap and the benches
    /// consume this instead of assuming every version has full shards.
    pub fn shard_chain(&self, version: u64, shard: u32) -> Result<Vec<(CkptKind, ChunkData)>> {
        let chain = incremental::resolve_chain(&self.store, &self.cfg.model_name, version)?;
        chain
            .iter()
            .map(|m| {
                Ok((m.kind, self.store.load_chunk(&self.cfg.model_name, m.version, shard, m.kind)?))
            })
            .collect()
    }

    /// Rebuild one slave replica's state from a master shard's chain:
    /// base full sync, then each delta chunk in order. Call once per
    /// master shard (the replica's router filters foreign ids; the
    /// master slot map filters rows the source shard no longer owns).
    /// Callers syncing many replicas should load via
    /// [`Self::shard_chain`] once and use [`Self::apply_chain_chunks`]
    /// per replica instead.
    pub fn slave_sync_chain(
        &self,
        replica: &Arc<SlaveShard>,
        version: u64,
        shard: u32,
    ) -> Result<()> {
        let map = self.master_router.snapshot();
        Self::apply_chain_chunks(replica, &self.shard_chain(version, shard)?, Some((&map, shard)))
    }

    /// Apply pre-loaded chain chunks to one replica (base → deltas).
    ///
    /// `owner` = (current *master* slot map, the chain's source shard).
    /// Chunks sealed before a live migration still carry moved rows at
    /// pre-move values; without the filter, replaying the donor's chain
    /// after the recipient's resurrects the stale copy — the moved row
    /// silently rolls back. Pass `None` only when no reshard can have
    /// happened (uniform map from epoch 0).
    pub fn apply_chain_chunks(
        replica: &Arc<SlaveShard>,
        chain: &[(CkptKind, ChunkData)],
        owner: Option<(&crate::reshard::SlotMap, u32)>,
    ) -> Result<()> {
        for (kind, bytes) in chain {
            match kind {
                CkptKind::Base => {
                    replica.full_sync_from_snapshot_owned(bytes, owner)?;
                }
                CkptKind::Delta => {
                    replica.apply_delta_snapshot_owned(bytes, owner)?;
                }
            }
        }
        Ok(())
    }

    /// Control tick: jittered checkpoints + feature expire + failure
    /// detection + downgrade evaluation. Returns an executed downgrade
    /// plan if one fired.
    pub fn control_tick(&self) -> Result<Option<DowngradePlan>> {
        if self.scheduler.checkpoint_due() {
            self.checkpoint()?;
        }
        if self.cfg.feature_ttl_ms > 0 {
            for m in &self.masters {
                m.expire_features_pooled(self.cfg.feature_ttl_ms, self.sync_pool.as_deref());
            }
        }
        // Evaluate the declared alert rules on the coordinator's cadence:
        // the same tick that feeds the domino also walks `window_auc_low`
        // (and the lag/WAL rules) through pending→firing, so a triggered
        // rollback always has a firing rule and journal trail behind it.
        crate::alerts::evaluate("coordinator");
        let snap = self.monitor.snapshot();
        let fire = {
            let mut domino = self.domino.lock().unwrap();
            snap.samples > 0 && domino.observe(snap.window_auc)
        };
        if fire {
            let strategy = self.domino.lock().unwrap().strategy;
            match self.vm.plan(&self.store, strategy) {
                Ok(plan) => {
                    self.execute_downgrade(&plan)?;
                    crate::alerts::journal(
                        "degradation",
                        "window_auc_low",
                        &format!(
                            "domino rollback v{} -> v{} (window_auc {:.6}, strategy {:?})",
                            plan.from_version, plan.target_version, snap.window_auc, strategy
                        ),
                        0,
                    );
                    return Ok(Some(plan));
                }
                Err(Error::State(_)) => return Ok(None), // nothing to roll to
                Err(e) => return Err(e),
            }
        }
        Ok(None)
    }

    /// Execute a downgrade (§4.3.2b): freeze masters, roll master state
    /// back to the target checkpoint, rebuild every slave replica from the
    /// same checkpoint (full sync), fast-forward scatters past the stale
    /// queue tail, unfreeze.
    pub fn execute_downgrade(&self, plan: &DowngradePlan) -> Result<()> {
        for m in &self.masters {
            m.set_frozen(true);
        }
        let result = (|| -> Result<()> {
            for m in &self.masters {
                m.restore_chain(&self.store, plan.target_version, m.shard_id as usize)?;
            }
            // Rollback across a reshard epoch: restored chains predate
            // the slot moves, so re-apply current ownership before
            // anything streams.
            let map = self.master_router.snapshot();
            if map.epoch > 0 {
                for m in &self.masters {
                    m.purge_foreign_rows(&map);
                }
            }
            // Slaves: clear + chain sync from the rolled-back lineage
            // (base + deltas), then skip the queue's poisoned tail (new
            // master state will stream from the current end). Chains are
            // loaded once per master and shared across replicas — this
            // is the latency-critical rollback path.
            let chains: Vec<Vec<(CkptKind, ChunkData)>> = self
                .masters
                .iter()
                .map(|m| self.shard_chain(plan.target_version, m.shard_id))
                .collect::<Result<_>>()?;
            for (sidx, shard) in self.slaves.iter().enumerate() {
                for (ridx, replica) in shard.iter().enumerate() {
                    replica.clear();
                    for (m, chain) in self.masters.iter().zip(&chains) {
                        Self::apply_chain_chunks(replica, chain, Some((&map, m.shard_id)))?;
                    }
                    replica.set_version(plan.target_version);
                    self.scatters[sidx][ridx].lock().unwrap().seek_to_latest()?;
                }
            }
            // Post-rollback durability hygiene: the WAL tail belongs to
            // the abandoned lineage, and the next checkpoint must reseed
            // a base (the rolled-back state has no chain to delta onto).
            if self.cfg.ckpt_mode == CkptMode::Incremental {
                let manifest =
                    self.store.load_manifest(&self.cfg.model_name, plan.target_version)?;
                for (i, m) in self.masters.iter().enumerate() {
                    let cut = manifest.epochs.get(i).copied().unwrap_or(0);
                    // Every master was just chain-restored, so a crash-time
                    // suspension can be lifted too.
                    self.journals[i].lock().unwrap().resume(cut, m.dense_versions());
                }
                for (p, off) in self.wal.latest_offsets().iter().enumerate() {
                    self.wal.trim_until(p as u32, *off)?;
                }
                self.scheduler.force_base_next();
            }
            Ok(())
        })();
        for m in &self.masters {
            m.set_frozen(false);
        }
        result?;
        // The rollback rewrote slave state outside the scatter stream, so
        // cached rows have no invalidation signal: drop them wholesale.
        self.serving_cache.clear();
        crate::alerts::journal(
            "degradation",
            "serving_cache_clear",
            &format!("rollback to v{} dropped the hot-id cache", plan.target_version),
            0,
        );
        self.vm.commit(plan);
        Ok(())
    }

    /// Manual version switch (the paper's "person can specify the
    /// appropriate version ... manually").
    pub fn switch_version(&self, target_version: u64) -> Result<()> {
        let manifest = self.store.load_manifest(&self.cfg.model_name, target_version)?;
        let plan = DowngradePlan {
            from_version: self.vm.current(),
            target_version,
            queue_offsets: manifest.queue_offsets,
            target_metric: manifest.metric,
        };
        self.execute_downgrade(&plan)
    }

    // -- failure injection + recovery (E4) -------------------------------------

    /// Kill a slave replica (serving fails over to its peers).
    pub fn kill_slave(&self, shard: usize, replica: usize) {
        self.slaves[shard][replica].set_healthy(false);
    }

    /// Recover a slave replica: warm-start from the newest checkpoint
    /// chain (base → delta chunks), then replay the queue from the
    /// checkpoint's recorded offsets (§4.2.1b's "external queue as the
    /// real-time incremental backup").
    pub fn recover_slave(&self, shard: usize, replica: usize) -> Result<()> {
        let version = self
            .store
            .latest_version(&self.cfg.model_name)
            .ok_or_else(|| Error::Checkpoint("no checkpoint to recover from".into()))?;
        let manifest = self.store.load_manifest(&self.cfg.model_name, version)?;
        let target = &self.slaves[shard][replica];
        target.clear();
        for m in &self.masters {
            self.slave_sync_chain(target, version, m.shard_id)?;
        }
        target.set_version(version);
        // Seek the replica's scatter to the checkpoint offsets of its
        // subscribed partitions, then drain to catch up.
        {
            let mut sc = self.scatters[shard][replica].lock().unwrap();
            let offsets: Vec<u64> = sc
                .partitions()
                .iter()
                .map(|p| manifest.queue_offsets.get(*p as usize).copied().unwrap_or(0))
                .collect();
            sc.seek(&offsets)?;
            sc.poll(Duration::ZERO)?;
        }
        target.set_healthy(true);
        self.groups[shard].reset_failures();
        // Chain restore bypassed the scatter taps; cached rows for this
        // shard may predate the recovered state. Dropping everything is
        // cheaper than tracking which stripes the chain touched.
        self.serving_cache.clear();
        crate::alerts::journal(
            "recovery",
            "slave_recovered",
            &format!("shard {shard} replica {replica} rebuilt from v{version}"),
            0,
        );
        Ok(())
    }

    /// Crash a master shard (replaces it with an empty shard object).
    /// Returns the dead shard's row count for verification.
    pub fn crash_master(&mut self, shard: usize) -> Result<usize> {
        let rows = self.masters[shard].total_rows();
        // Quiesce the dead shard's WAL journal: a sync tick between crash
        // and recovery must not log the blank replacement's state, or
        // recovery would replay it over the restored rows. recover_master
        // re-arms the journal.
        self.journals[shard].lock().unwrap().suspend();
        let fresh = Arc::new(MasterShard::with_row_store(
            shard as u32,
            self.spec.clone(),
            Some(self.engine.clone()),
            self.cfg.entry_threshold,
            self.cfg.table_stripes as usize,
            self.cfg.table_row_store,
            self.clock.clone(),
        )?);
        fresh.set_route_guard(self.master_router.clone());
        // Rewire: gather + trainer channels point at the new object.
        self.gathers[shard] = Mutex::new(Gather::with_pool(
            fresh.clone(),
            self.cfg.gather_mode,
            self.clock.clone(),
            self.sync_pool.clone(),
        ));
        self.masters[shard] = fresh;
        self.rewire_trainer();
        Ok(rows)
    }

    /// Cold-start routing recovery: restore the slot map sealed into the
    /// newest checkpoint manifest when it is ahead of the live router.
    /// A cluster restarted from disk has no scheduler metadata, so
    /// without this the post-restore foreign-row purge (and every routed
    /// push) would run against the implicit uniform map — wrong the
    /// moment any slot had migrated. Returns the routing epoch in
    /// effect afterwards. No-op when the live router is already at or
    /// past the manifest's epoch (a scrape-fed cluster wins).
    pub fn recover_routing(&self) -> Result<u64> {
        let version = match self.store.latest_version(&self.cfg.model_name) {
            Some(v) => v,
            None => return Ok(self.master_router.epoch()),
        };
        let manifest = self.store.load_manifest(&self.cfg.model_name, version)?;
        if manifest.route_epoch > self.master_router.epoch() && !manifest.slot_map.is_empty() {
            let map = crate::reshard::SlotMap::from_bytes(&manifest.slot_map)?;
            self.master_router.install(map)?;
        }
        Ok(self.master_router.epoch())
    }

    /// Partial recovery of one master shard. Incremental mode: base →
    /// delta chain → WAL-tail replay (byte-identical, including row
    /// metadata — the chunks carry it). Full mode: newest checkpoint +
    /// replay of the shard's own sync partition (§4.2.1b/e).
    pub fn recover_master(&self, shard: usize) -> Result<u64> {
        // Routing first: the foreign-row purges below must see the slot
        // map the checkpoint was cut under, not the boot-time default.
        self.recover_routing()?;
        if self.cfg.ckpt_mode == CkptMode::Incremental {
            let version = self
                .store
                .latest_version(&self.cfg.model_name)
                .ok_or_else(|| Error::Checkpoint("no checkpoint to recover from".into()))?;
            let master = &self.masters[shard];
            let tip = master.restore_chain(&self.store, version, shard)?;
            let from = tip.wal_offsets.get(shard).copied().unwrap_or(0);
            incremental::replay_wal(master, &self.wal, shard as u32, from)?;
            // Replayed rows are stamped dirty; seal the journal frontier
            // at a fresh cut so they are re-captured by the next chunk
            // (they are already in the WAL) but not re-journaled. This
            // also lifts the crash-time suspension.
            let cut = master.cut_epoch();
            self.journals[shard].lock().unwrap().resume(cut, master.dense_versions());
            // Elastic-reshard hygiene: the restored chain predates any
            // slot moves; drop rows the current map assigns elsewhere.
            let map = self.master_router.snapshot();
            if map.epoch > 0 {
                master.purge_foreign_rows(&map);
            }
            return Ok(version);
        }
        let version = self.scheduler.recover_shard(&self.masters[shard])?;
        let manifest = self.store.load_manifest(&self.cfg.model_name, version)?;
        // Replay this shard's partition from the checkpoint offset: sync
        // batches carry full (z, n, w) rows, so upserting them restores
        // every post-checkpoint update.
        let partition_id =
            crate::sync::router::partition_of_shard(shard as u32, self.topic.partition_count() as u32);
        let start = manifest.queue_offsets.get(partition_id as usize).copied().unwrap_or(0);
        let partition = self.topic.partition(partition_id as usize)?;
        let mut offset = start.max(partition.earliest_offset());
        let master = &self.masters[shard];
        let mut raw = Vec::new();
        loop {
            let records = partition.fetch(offset, 256, Duration::ZERO)?;
            if records.is_empty() {
                break;
            }
            // Decode the whole fetch chunk, then replay it coalesced: one
            // stripe-lock acquisition per busy stripe per chunk.
            let mut chunk: Vec<crate::proto::SyncBatch> = Vec::with_capacity(records.len());
            for rec in &records {
                offset = rec.offset + 1;
                crate::codec::decompress_into(&rec.payload, &mut raw)?;
                let batch =
                    <crate::proto::SyncBatch as crate::codec::Decode>::from_bytes(&raw)?;
                if batch.shard != shard as u32 || !batch.dense.is_empty() {
                    continue;
                }
                chunk.push(batch);
            }
            master.replay_sync_batches(&chunk)?;
        }
        let map = self.master_router.snapshot();
        if map.epoch > 0 {
            master.purge_foreign_rows(&map);
        }
        Ok(version)
    }

    // -- elastic resharding ------------------------------------------------------

    /// Live slot migration: move `slots` from master `donor` to
    /// `recipient` under full traffic, with zero dropped updates and
    /// byte-identical moved state. The sequence (see `reshard` for the
    /// protocol pieces):
    ///
    /// 1. widen every scatter to all partitions (moved ids' updates will
    ///    originate from the recipient's partition after cutover);
    /// 2. base copy + dirty-epoch catch-up while the donor keeps
    ///    training;
    /// 3. seal the moving slots (pushes NACK into the trainer client's
    ///    retry loop), take the final hand-off delta;
    /// 4. flush the donor's pending sync window and wait until every
    ///    scatter has consumed past it — from here on, any newer value
    ///    for a moved id can only arrive via the recipient's partition,
    ///    so cross-partition ordering cannot regress a slave;
    /// 5. durability: the drain journaled the recipient's migrated rows
    ///    to its WAL (incremental mode); full mode backs them into the
    ///    recipient's queue partition — either way the new ownership is
    ///    recoverable before the routing changes or anything is deleted;
    /// 6. cutover: install + publish the bumped slot map (trainer
    ///    retries re-route to the recipient);
    /// 7. release: purge the moved rows from the donor (silently — the
    ///    recipient's lineage owns them) and lift the seal.
    pub fn migrate_slots(
        &self,
        donor: u32,
        recipient: u32,
        slots: &[u16],
    ) -> Result<MigrationReport> {
        let map = self.master_router.snapshot();
        if donor == recipient || donor >= map.shards || recipient >= map.shards {
            return Err(Error::Routing(format!(
                "migrate {donor} -> {recipient} in a {}-shard cluster",
                map.shards
            )));
        }
        for &s in slots {
            if s as usize >= map.slots() || map.shard_of_slot(s) != donor {
                return Err(Error::State(format!(
                    "slot {s} not owned by donor {donor} at epoch {}",
                    map.epoch
                )));
            }
        }
        // 1. Widen subscriptions before any routing changes.
        for shard in &self.scatters {
            for sc in shard {
                sc.lock().unwrap().subscribe_all()?;
            }
        }
        // 2. Online copy.
        let mut transfer = SlotTransfer::new(
            &self.masters[donor as usize],
            &self.masters[recipient as usize],
            slots,
            map.slots(),
        )?;
        transfer.run_catchup(&MigrationOpts::default())?;
        // 3. Hand-off window. Every fallible step between seal and
        // cutover aborts the transfer on error (seal lifted, donor stays
        // authoritative, map untouched) — a failed migration must never
        // leave the slots sealed forever.
        if let Err(e) = transfer.seal() {
            // Nothing was sealed (another hand-off holds the donor) —
            // plain error, no abort.
            return Err(e);
        }
        let sealed_result =
            transfer.final_sync().and_then(|()| self.flush_and_drain_donor(donor));
        if let Err(e) = sealed_result {
            transfer.abort();
            return Err(e);
        }
        // 5. Durability before the cutover (so an error here can still
        // abort cleanly). Incremental mode: the drain already journaled
        // the recipient's (dirty) migrated rows to its WAL, so chain +
        // WAL replay recovers them. Full mode has no journal — back the
        // moved rows into the recipient's queue partition instead (the
        // mode's own §4.2.1b incremental backup; a full-model snapshot
        // here would hold the seal for minutes at scale): a recipient
        // crash replays its partition and restores them, and slaves see
        // idempotent re-upserts of values they already hold.
        if self.cfg.ckpt_mode == CkptMode::Full {
            if let Err(e) = self.backup_moved_rows_to_queue(recipient, transfer.slot_set()) {
                transfer.abort();
                return Err(e);
            }
        }
        // 6. Cutover.
        let moves: Vec<(u16, u32)> = slots.iter().map(|&s| (s, recipient)).collect();
        let bumped = match map.rebalanced(&moves) {
            Ok(b) => b,
            Err(e) => {
                transfer.abort();
                return Err(e);
            }
        };
        let installed = match self.master_router.install(bumped) {
            Ok(m) => m,
            Err(e) => {
                transfer.abort();
                return Err(e);
            }
        };
        // The cutover is installed; from here the migration must complete
        // (release the donor) even if the meta publish raced a newer
        // epoch — surface that error after the release.
        let published = self.scheduler.publish_slot_map(&installed);
        // 7. Release the donor.
        let report = transfer.finish()?;
        published?;
        let labels = [("role", "master".to_string())];
        let rows = (report.base_rows + report.catchup_rows + report.final_rows) as u64;
        crate::metrics::counter("weips_migrations_total", &labels).fetch_add(1, Ordering::Relaxed);
        crate::metrics::counter("weips_migration_slots_moved_total", &labels)
            .fetch_add(report.slots_moved as u64, Ordering::Relaxed);
        crate::metrics::counter("weips_migration_rows_moved_total", &labels)
            .fetch_add(rows, Ordering::Relaxed);
        crate::alerts::journal(
            "reshard",
            "slots_migrated",
            &format!(
                "donor {donor} -> recipient {recipient}: {} slots, {rows} rows",
                report.slots_moved
            ),
            0,
        );
        Ok(report)
    }

    /// Full-mode migration durability: append the recipient's copy of
    /// the moved rows to its queue partition as ordinary full-value sync
    /// batches. [`Self::recover_master`]'s partition replay then
    /// restores them after a recipient crash; slaves consuming the
    /// partition apply idempotent re-upserts. Runs under the donor seal,
    /// so the copied values are final.
    fn backup_moved_rows_to_queue(
        &self,
        recipient: u32,
        slots: &crate::reshard::SlotSet,
    ) -> Result<()> {
        let now = self.clock.now_ms();
        let sections = self.masters[recipient as usize].collect_slot_delta(None, slots);
        for (table, rows, _) in sections {
            if rows.is_empty() {
                continue;
            }
            let batch = crate::proto::SyncBatch {
                model: self.cfg.model_name.clone(),
                table,
                shard: recipient,
                seq: 0,
                created_ms: now,
                entries: rows
                    .into_iter()
                    .map(|r| crate::proto::SyncEntry {
                        id: r.id,
                        op: crate::proto::SyncOp::Upsert(r.values),
                    })
                    .collect(),
                dense: Vec::new(),
            };
            self.pushers[recipient as usize].push(&batch)?;
        }
        Ok(())
    }

    /// Migration step 4: flush the donor's pending gather window (gather
    /// lock held across the push so a concurrent sync pump cannot
    /// interleave an older window behind it) and wait until every scatter
    /// has consumed the donor partition past the flush point. Bounded: a
    /// consumer that stops advancing fails the migration instead of
    /// spinning forever, and empty rounds back off briefly instead of
    /// busy-polling the scatter mutexes.
    fn flush_and_drain_donor(&self, donor: u32) -> Result<()> {
        {
            let mut g = self.gathers[donor as usize].lock().unwrap();
            let batches = g.flush_now();
            self.pushers[donor as usize].push_all(&batches)?;
        }
        self.journal_wal()?;
        let donor_partition = crate::sync::router::partition_of_shard(
            donor,
            self.topic.partition_count() as u32,
        );
        let drain_target = self.topic.partition(donor_partition as usize)?.latest_offset();
        let deadline = std::time::Instant::now() + Duration::from_secs(30);
        loop {
            let mut behind = false;
            for shard in &self.scatters {
                for sc in shard {
                    let mut sc = sc.lock().unwrap();
                    sc.poll(Duration::ZERO)?;
                    match sc.offset_for(donor_partition) {
                        Some(o) if o >= drain_target => {}
                        _ => behind = true,
                    }
                }
            }
            if !behind {
                return Ok(());
            }
            if std::time::Instant::now() >= deadline {
                return Err(Error::State(format!(
                    "migration drain timed out: a scatter never consumed donor partition \
                     {donor_partition} to offset {drain_target}"
                )));
            }
            std::thread::sleep(Duration::from_micros(200));
        }
    }

    fn rewire_trainer(&mut self) {
        let channels: Vec<Channel> = self
            .masters
            .iter()
            .map(|m| {
                Channel::local(Arc::new(MasterService {
                    shard: m.clone(),
                    store: Some(self.store.clone()),
                }))
            })
            .collect();
        self.trainer = Trainer::new(
            self.engine.clone(),
            self.spec.clone(),
            ShardedClient::with_router(
                &self.cfg.model_name,
                channels,
                self.master_router.clone(),
            ),
            self.monitor.clone(),
        );
    }

    /// Inject parameter corruption into every master shard (E5: the
    /// "abnormal change" a downgrade must catch): flips the sign and
    /// inflates all first-order serving weights.
    pub fn corrupt_model(&self) -> Result<()> {
        for m in &self.masters {
            m.corrupt_for_test(8.0)?;
        }
        Ok(())
    }

    // -- background pumps (wall-clock mode) -------------------------------------

    /// Spawn sync + control pump threads (examples / CLI local mode).
    pub fn start_pumps(self: &Arc<Self>, sync_interval: Duration, control_interval: Duration) {
        if self.pumps_running.swap(true, Ordering::SeqCst) {
            return;
        }
        let me = self.clone();
        let running = self.pumps_running.clone();
        let h1 = std::thread::Builder::new()
            .name("weips-sync-pump".into())
            .spawn(move || {
                while running.load(Ordering::Acquire) {
                    let _ = me.sync_tick();
                    std::thread::sleep(sync_interval);
                }
            })
            .expect("spawn sync pump");
        let me = self.clone();
        let running = self.pumps_running.clone();
        let h2 = std::thread::Builder::new()
            .name("weips-control-pump".into())
            .spawn(move || {
                while running.load(Ordering::Acquire) {
                    let _ = me.control_tick();
                    std::thread::sleep(control_interval);
                }
            })
            .expect("spawn control pump");
        self.pump_handles.lock().unwrap().extend([h1, h2]);
    }

    /// Stop the background pumps.
    pub fn stop_pumps(&self) {
        self.pumps_running.store(false, Ordering::SeqCst);
        for h in self.pump_handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }

    /// Serve this process's `/metrics` endpoint (all in-process roles
    /// share the global registry, so one endpoint exposes the whole
    /// local cluster). Keep the returned server alive for as long as
    /// scrapes should succeed; use port 0 for an ephemeral port.
    pub fn serve_metrics(&self, addr: &str) -> Result<crate::metrics::http::MetricsServer> {
        Ok(crate::metrics::http::MetricsServer::serve(addr)?)
    }
}

impl Drop for LocalCluster {
    fn drop(&mut self) {
        self.stop_pumps();
        if self.owns_data_dir {
            let _ = std::fs::remove_dir_all(&self.data_dir);
        }
    }
}
