//! # WeiPS — symmetric fusion parameter server for large-scale online learning
//!
//! Reproduction of *WeiPS: a symmetric fusion model framework for large-scale
//! online learning* (Sina Weibo, 2020) as a three-layer Rust + JAX + Pallas
//! stack. The Rust layer (this crate) is the entire runtime system: parameter
//! servers (master/slave), the streaming synchronization pipeline, the
//! scheduler, workers, and every substrate the paper depends on (partitioned
//! queue, metadata store, checkpoint storage). Model math is authored in JAX
//! (+ Pallas kernels) and AOT-compiled to HLO executed through PJRT — Python
//! is never on the request path.
//!
//! See `DESIGN.md` for the full system inventory and experiment index.

// Style lints deliberately relaxed: this crate reimplements ecosystem
// substrates (hash maps, histograms, codecs, a prop-test harness) whose
// idiomatic shapes trip pedantic style checks; correctness lints stay on
// and CI runs `clippy -- -D warnings` over what remains.
#![allow(
    clippy::inherent_to_string,
    clippy::len_without_is_empty,
    clippy::new_without_default,
    clippy::needless_range_loop,
    clippy::manual_range_contains,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::should_implement_trait,
    clippy::result_large_err
)]

pub mod alerts;
pub mod cli;
pub mod codec;
pub mod config;
pub mod coordinator;
pub mod downgrade;
pub mod error;
pub mod joiner;
pub mod meta;
pub mod metrics;
pub mod monitor;
pub mod net;
pub mod optim;
pub mod proto;
pub mod queue;
pub mod replica;
pub mod reshard;
pub mod runtime;
pub mod sample;
pub mod scheduler;
pub mod server;
pub mod storage;
pub mod sync;
pub mod table;
pub mod trace;
pub mod util;
pub mod worker;

pub use error::{Error, Result};
