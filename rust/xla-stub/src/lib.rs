//! Offline stub of the `xla` PJRT bindings.
//!
//! The real crate wraps the XLA PJRT C API (client construction, HLO
//! compilation, buffer execution). This build environment has no PJRT
//! runtime, so this stub preserves the exact API surface the `weips`
//! runtime layer compiles against while failing *at runtime* on any path
//! that would need the real PJRT machinery (module compilation/execution).
//!
//! Host-side `Literal` handling is implemented for real (it is plain byte
//! shuffling), so code that only constructs/destructures literals works.
//! `Engine::load` only touches PJRT lazily per-module, and every test and
//! bench that needs compiled modules already skips when the AOT artifacts
//! are absent — which is exactly the situation in which this stub is the
//! linked implementation.

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error` (string-backed here).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} requires the PJRT runtime, which is not available in this offline build \
         (the xla crate is stubbed; see rust/xla-stub)"
    ))
}

/// Element types the weips runtime uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
}

/// A host-side literal: shape + raw little-endian bytes.
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    data: Vec<u8>,
}

/// Types a literal's payload can be viewed as.
pub trait NativeType: Copy {
    /// Size of one element in bytes.
    const SIZE: usize;
    /// Decode one little-endian element.
    fn from_le_bytes(chunk: &[u8]) -> Self;
}

impl NativeType for f32 {
    const SIZE: usize = 4;
    fn from_le_bytes(chunk: &[u8]) -> Self {
        f32::from_le_bytes(chunk.try_into().expect("4-byte chunk"))
    }
}

impl Literal {
    /// Build a literal from a shape and raw (little-endian) bytes.
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal, Error> {
        let elems: usize = dims.iter().product();
        let want = elems * 4;
        if data.len() != want {
            return Err(Error(format!(
                "literal shape {dims:?} wants {want} bytes, got {}",
                data.len()
            )));
        }
        Ok(Literal { ty, dims: dims.to_vec(), data: data.to_vec() })
    }

    /// Element type.
    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    /// Shape dimensions.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Copy the payload out as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        if self.data.len() % T::SIZE != 0 {
            return Err(Error(format!(
                "literal payload of {} bytes is not a multiple of {}",
                self.data.len(),
                T::SIZE
            )));
        }
        Ok(self.data.chunks_exact(T::SIZE).map(T::from_le_bytes).collect())
    }

    /// Destructure a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(unavailable("tuple literal destructuring"))
    }
}

/// Parsed HLO module (stub: never constructible offline).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO text file. Always fails in the stub: turning HLO text
    /// into a module proto is PJRT/XLA functionality.
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto, Error> {
        Err(unavailable(&format!(
            "loading HLO module {}",
            path.as_ref().display()
        )))
    }
}

/// XLA computation handle (stub).
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a module proto as a computation.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Compiled executable handle (stub: never constructible offline).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

/// Device buffer handle (stub: never constructible offline).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable("device-to-host transfer"))
    }
}

impl PjRtLoadedExecutable {
    /// Execute the program on the given arguments.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("executable execution"))
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Construct the CPU client. Succeeds so that hosts can build engine
    /// objects; the failure surfaces lazily at first compile/execute.
    pub fn cpu() -> Result<PjRtClient, Error> {
        Ok(PjRtClient { _private: () })
    }

    /// Compile a computation.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("HLO compilation"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trips_f32() {
        let vals = [1.0f32, -2.5, 0.0, 3.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 2], &bytes).unwrap();
        assert_eq!(lit.dims(), &[2, 2]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vals);
    }

    #[test]
    fn literal_rejects_wrong_sizes() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &[0u8; 4]).is_err()
        );
    }

    #[test]
    fn pjrt_paths_fail_gracefully() {
        let client = PjRtClient::cpu().unwrap();
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo.txt").is_err());
        let exec_err = client
            .compile(&XlaComputation { _private: () })
            .map(|_| ())
            .unwrap_err();
        assert!(exec_err.to_string().contains("PJRT"), "{exec_err}");
    }
}
