//! Integration: multi-level fault tolerance (§4.2).
//!
//! Hot backup: a slave replica dies, serving continues through its peers
//! and the replica catches back up via full sync + offset replay.
//! Cold backup: a master shard crashes and recovers *partially* (only
//! that shard) from checkpoint + its own queue partition's incremental
//! backup, restoring post-checkpoint updates too.

use weips::config::{ClusterConfig, GatherMode, ModelKind};
use weips::coordinator::{ClusterOpts, LocalCluster};
use weips::proto::SparsePull;
use weips::sample::WorkloadConfig;

fn artifacts_ready() -> bool {
    weips::runtime::default_artifacts_dir().join("manifest.json").exists()
}

fn cluster() -> LocalCluster {
    LocalCluster::new(ClusterOpts {
        cluster: ClusterConfig {
            model_kind: ModelKind::Lr,
            master_shards: 4,
            slave_shards: 2,
            slave_replicas: 3,
            queue_partitions: 4,
            gather_mode: GatherMode::Realtime,
            ..Default::default()
        },
        workload: WorkloadConfig { ids_per_field: 1_000, seed: 21, ..Default::default() },
        ..Default::default()
    })
    .expect("cluster")
}

#[test]
fn slave_failover_keeps_serving_and_recovery_catches_up() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let c = cluster();
    for _ in 0..8 {
        c.train_step().unwrap();
        c.sync_tick().unwrap();
    }
    c.flush_sync().unwrap();
    c.checkpoint().unwrap();

    // Kill replica 0 of both shards: predictions must still succeed.
    c.kill_slave(0, 0);
    c.kill_slave(1, 0);
    let reqs = c.serving_requests(8);
    let preds = c.predict(&reqs).unwrap();
    assert_eq!(preds.len(), 8);

    // Train more while the replica is down (it misses these updates).
    for _ in 0..5 {
        c.train_step().unwrap();
        c.sync_tick().unwrap();
    }
    c.flush_sync().unwrap();

    // Recover replica (0,0): full sync from checkpoint + replay.
    c.recover_slave(0, 0).unwrap();
    let healthy = &c.slaves[0][1];
    let recovered = &c.slaves[0][0];
    assert!(recovered.is_healthy());

    // Drain any remaining queue tail for the recovered replica.
    c.flush_sync().unwrap();
    // Same rows served as a replica that never died.
    assert_eq!(recovered.total_rows(), healthy.total_rows());
    // Spot-check value equality on the healthy replica's ids.
    let reqs = c.serving_requests(16);
    for ids in &reqs {
        for &id in ids {
            let router = weips::sync::Router::new(c.cfg.slave_shards);
            if router.shard_of(id) != 0 {
                continue;
            }
            let pull = |s: &std::sync::Arc<weips::server::SlaveShard>| {
                s.sparse_pull(&SparsePull {
                    model: "ctr".into(),
                    table: "w".into(),
                    ids: vec![id],
                    slot: "w".into(),
                })
                .unwrap()
                .values[0]
            };
            assert!((pull(recovered) - pull(healthy)).abs() < 1e-6, "id {id}");
        }
    }
}

#[test]
fn all_replicas_down_is_unavailable_not_wrong() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let c = cluster();
    for _ in 0..3 {
        c.train_step().unwrap();
    }
    c.flush_sync().unwrap();
    for r in 0..3 {
        c.kill_slave(0, r);
    }
    let reqs = c.serving_requests(4);
    // Some requests route to shard 0 -> must error, not return stale junk.
    let result = c.predict(&reqs);
    assert!(result.is_err(), "predictions served with no healthy replica");
}

#[test]
fn master_partial_recovery_restores_post_checkpoint_updates() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut c = cluster();
    for _ in 0..6 {
        c.train_step().unwrap();
        c.sync_tick().unwrap();
    }
    c.flush_sync().unwrap();
    c.checkpoint().unwrap();
    // Post-checkpoint updates (the incremental backup must capture these).
    for _ in 0..6 {
        c.train_step().unwrap();
        c.sync_tick().unwrap();
    }
    c.flush_sync().unwrap();

    let victim = 2usize;
    let reference = c.masters[victim].snapshot();
    let rows_before = c.crash_master(victim).unwrap();
    assert!(rows_before > 0);
    assert_eq!(c.masters[victim].total_rows(), 0);

    c.recover_master(victim).unwrap();
    let recovered_rows = c.masters[victim].total_rows();
    assert_eq!(
        recovered_rows, rows_before,
        "partial recovery row count {recovered_rows} != pre-crash {rows_before}"
    );
    // Value-level equality vs the pre-crash snapshot.
    assert_eq!(
        c.masters[victim].snapshot().len(),
        reference.len(),
        "snapshot shape differs after recovery"
    );
    // Other shards untouched (partial recovery, not cluster restart).
    for (i, m) in c.masters.iter().enumerate() {
        if i != victim {
            assert!(m.total_rows() > 0);
        }
    }
    // Training continues after recovery.
    for _ in 0..2 {
        c.train_step().unwrap();
    }
}

#[test]
fn checkpoint_versions_rotate_with_gc() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let c = cluster();
    for round in 0..8 {
        for _ in 0..2 {
            c.train_step().unwrap();
        }
        c.flush_sync().unwrap();
        let v = c.checkpoint().unwrap();
        assert_eq!(v, round + 1);
    }
    let versions = c.store.list_versions("ctr");
    // keep=5 local + remote_every=4 replicated survivors.
    assert!(versions.len() >= 5, "{versions:?}");
    assert!(versions.contains(&8));
    assert!(versions.contains(&4), "remote-replicated v4 survives: {versions:?}");
}
