//! Integration: multi-level fault tolerance (§4.2).
//!
//! Hot backup: a slave replica dies, serving continues through its peers
//! and the replica catches back up via full sync + offset replay.
//! Cold backup: a master shard crashes and recovers *partially* (only
//! that shard) from checkpoint + its own queue partition's incremental
//! backup, restoring post-checkpoint updates too.
//!
//! Incremental durability (artifact-free section at the bottom): a
//! killed master shard is rebuilt from a base chunk + ≥2 delta chunks +
//! the WAL tail to **byte-identical** state versus the uninterrupted
//! run, and hostile chunk bytes / manifest chains fail cleanly.

use std::sync::Arc;

use weips::config::{ClusterConfig, GatherMode, ModelKind, ModelSpec};
use weips::coordinator::{ClusterOpts, LocalCluster};
use weips::meta::MetaStore;
use weips::proto::{SparsePull, SparsePush};
use weips::queue::WalLog;
use weips::runtime::ModelConfig;
use weips::sample::WorkloadConfig;
use weips::scheduler::{CkptPolicy, Scheduler};
use weips::server::master::MasterShard;
use weips::storage::incremental::{self, IncrPolicy, WalJournal};
use weips::storage::{CheckpointStore, CkptKind};
use weips::util::clock::ManualClock;

fn artifacts_ready() -> bool {
    weips::runtime::default_artifacts_dir().join("manifest.json").exists()
}

fn cluster() -> LocalCluster {
    LocalCluster::new(ClusterOpts {
        cluster: ClusterConfig {
            model_kind: ModelKind::Lr,
            master_shards: 4,
            slave_shards: 2,
            slave_replicas: 3,
            queue_partitions: 4,
            gather_mode: GatherMode::Realtime,
            ..Default::default()
        },
        workload: WorkloadConfig { ids_per_field: 1_000, seed: 21, ..Default::default() },
        ..Default::default()
    })
    .expect("cluster")
}

#[test]
fn slave_failover_keeps_serving_and_recovery_catches_up() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let c = cluster();
    for _ in 0..8 {
        c.train_step().unwrap();
        c.sync_tick().unwrap();
    }
    c.flush_sync().unwrap();
    c.checkpoint().unwrap();

    // Kill replica 0 of both shards: predictions must still succeed.
    c.kill_slave(0, 0);
    c.kill_slave(1, 0);
    let reqs = c.serving_requests(8);
    let preds = c.predict(&reqs).unwrap();
    assert_eq!(preds.len(), 8);

    // Train more while the replica is down (it misses these updates).
    for _ in 0..5 {
        c.train_step().unwrap();
        c.sync_tick().unwrap();
    }
    c.flush_sync().unwrap();

    // Recover replica (0,0): full sync from checkpoint + replay.
    c.recover_slave(0, 0).unwrap();
    let healthy = &c.slaves[0][1];
    let recovered = &c.slaves[0][0];
    assert!(recovered.is_healthy());

    // Drain any remaining queue tail for the recovered replica.
    c.flush_sync().unwrap();
    // Same rows served as a replica that never died.
    assert_eq!(recovered.total_rows(), healthy.total_rows());
    // Spot-check value equality on the healthy replica's ids.
    let reqs = c.serving_requests(16);
    for ids in &reqs {
        for &id in ids {
            let router = weips::sync::Router::new(c.cfg.slave_shards);
            if router.shard_of(id) != 0 {
                continue;
            }
            let pull = |s: &std::sync::Arc<weips::server::SlaveShard>| {
                s.sparse_pull(&SparsePull {
                    model: "ctr".into(),
                    table: "w".into(),
                    ids: vec![id],
                    slot: "w".into(),
                })
                .unwrap()
                .values[0]
            };
            assert!((pull(recovered) - pull(healthy)).abs() < 1e-6, "id {id}");
        }
    }
}

#[test]
fn all_replicas_down_is_unavailable_not_wrong() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let c = cluster();
    for _ in 0..3 {
        c.train_step().unwrap();
    }
    c.flush_sync().unwrap();
    for r in 0..3 {
        c.kill_slave(0, r);
    }
    let reqs = c.serving_requests(4);
    // Some requests route to shard 0 -> must error, not return stale junk.
    let result = c.predict(&reqs);
    assert!(result.is_err(), "predictions served with no healthy replica");
}

#[test]
fn master_partial_recovery_restores_post_checkpoint_updates() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut c = cluster();
    for _ in 0..6 {
        c.train_step().unwrap();
        c.sync_tick().unwrap();
    }
    c.flush_sync().unwrap();
    c.checkpoint().unwrap();
    // Post-checkpoint updates (the incremental backup must capture these).
    for _ in 0..6 {
        c.train_step().unwrap();
        c.sync_tick().unwrap();
    }
    c.flush_sync().unwrap();

    let victim = 2usize;
    let reference = c.masters[victim].snapshot();
    let rows_before = c.crash_master(victim).unwrap();
    assert!(rows_before > 0);
    assert_eq!(c.masters[victim].total_rows(), 0);

    c.recover_master(victim).unwrap();
    let recovered_rows = c.masters[victim].total_rows();
    assert_eq!(
        recovered_rows, rows_before,
        "partial recovery row count {recovered_rows} != pre-crash {rows_before}"
    );
    // Incremental recovery (chain + WAL tail) carries row metadata, so
    // the restored shard is byte-identical to the pre-crash snapshot.
    assert_eq!(
        c.masters[victim].snapshot(),
        reference,
        "snapshot differs after recovery"
    );
    // Other shards untouched (partial recovery, not cluster restart).
    for (i, m) in c.masters.iter().enumerate() {
        if i != victim {
            assert!(m.total_rows() > 0);
        }
    }
    // Training continues after recovery.
    for _ in 0..2 {
        c.train_step().unwrap();
    }
}

// ---------------------------------------------------------------------------
// Incremental durability (no AOT artifacts needed: scalar master shards)
// ---------------------------------------------------------------------------

fn mini_spec() -> ModelSpec {
    let cfg = ModelConfig {
        batch_train: 8,
        batch_predict: 2,
        fields: 4,
        dim: 2,
        hidden: 8,
        ftrl_block_rows: 64,
        ftrl_alpha: 0.05,
        ftrl_beta: 1.0,
        ftrl_l1: 1.0,
        ftrl_l2: 1.0,
    };
    ModelSpec::derive("ctr", ModelKind::Fm, &cfg)
}

fn mini_master(clock: &ManualClock) -> Arc<MasterShard> {
    Arc::new(MasterShard::new(0, mini_spec(), None, 1, Arc::new(clock.clone())).unwrap())
}

fn push_grads(m: &MasterShard, ids: std::ops::Range<u64>) {
    for id in ids {
        m.sparse_push(&SparsePush {
            model: "ctr".into(),
            table: "w".into(),
            ids: vec![id],
            grads: vec![(id % 7) as f32 * 0.3 + 0.5],
        })
        .unwrap();
        if id % 3 == 0 {
            m.sparse_push(&SparsePush {
                model: "ctr".into(),
                table: "v".into(),
                ids: vec![id],
                grads: vec![0.2, -0.2],
            })
            .unwrap();
        }
    }
}

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!(
        "weips-ft-{tag}-{}-{:x}",
        std::process::id(),
        weips::util::mono_ns()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// The acceptance path: kill a master, rebuild it from base + ≥2 delta
/// chunks + the WAL tail, and get back the *byte-identical* shard state
/// an uninterrupted run holds — including row metadata, tombstoned
/// (expired) rows and dense state.
#[test]
fn incremental_kill_and_recover_is_byte_identical() {
    let dir = tmp_dir("recover");
    let store = Arc::new(CheckpointStore::new(dir.join("ckpt"), None));
    let clock = ManualClock::new(0);
    let master = mini_master(&clock);
    let wal = WalLog::open(dir.join("wal"), 1).unwrap();
    let mut scheduler = Scheduler::new(
        MetaStore::new(Arc::new(clock.clone())),
        store.clone(),
        "ctr",
        CkptPolicy::default(),
        Arc::new(clock.clone()),
    );
    scheduler.set_incr_policy(IncrPolicy { base_every: 8, keep_chains: 2 });
    let mut journal = WalJournal::new(0);
    let masters = [master.clone()];

    let mut seal = |journal: &mut WalJournal| {
        let wal_offsets = wal.latest_offsets();
        let (v, kind, cuts) = scheduler
            .checkpoint_incremental(&masters, vec![], wal_offsets.clone(), 0.5)
            .unwrap();
        journal.reset(cuts[0], master.dense_versions());
        wal.trim_until(0, wal_offsets[0]).unwrap();
        (v, kind)
    };

    push_grads(&master, 0..600);
    journal.poll(&master, &wal, 1).unwrap();
    let (v1, k1) = seal(&mut journal);
    assert_eq!(k1, CkptKind::Base);

    push_grads(&master, 600..800);
    journal.poll(&master, &wal, 2).unwrap();
    let (v2, k2) = seal(&mut journal);
    assert_eq!(k2, CkptKind::Delta);

    // Overwrite live rows and expire a stale slice in the next window:
    // the delta must carry tombstones, not just upserts.
    clock.advance(10_000);
    push_grads(&master, 300..360);
    assert_eq!(master.expire_features(20_000), 0);
    let evicted = master.expire_features(9_000);
    assert!(evicted > 0, "expire found nothing to evict");
    journal.poll(&master, &wal, 3).unwrap();
    let (v3, k3) = seal(&mut journal);
    assert_eq!(k3, CkptKind::Delta);

    // WAL-only tail past the last sealed delta: two more windows.
    push_grads(&master, 800..900);
    journal.poll(&master, &wal, 4).unwrap();
    push_grads(&master, 340..352);
    journal.poll(&master, &wal, 5).unwrap();

    let reference = master.snapshot();

    // "Kill" the shard: a fresh object recovers chain + WAL tail.
    let fresh = mini_master(&clock);
    let tip = fresh.restore_chain(&store, v3, 0).unwrap();
    assert_eq!(tip.version, v3);
    let from = tip.wal_offsets.first().copied().unwrap_or(0);
    let replayed = incremental::replay_wal(&fresh, &wal, 0, from).unwrap();
    assert_eq!(replayed, 2, "expected exactly the two unsealed windows");
    assert_eq!(fresh.snapshot(), reference, "recovered state != uninterrupted run");
    assert_eq!(fresh.total_rows(), master.total_rows());

    // Both delta chunks really exist as distinct artifacts.
    assert!(store.load_chunk("ctr", v2, 0, CkptKind::Delta).is_ok());
    assert!(store.load_chunk("ctr", v3, 0, CkptKind::Delta).is_ok());
    assert_eq!(store.load_manifest("ctr", v3).unwrap().parent, v2);
    assert_eq!(store.load_manifest("ctr", v2).unwrap().parent, v1);

    // Process restart: reopen the WAL from disk and recover again.
    drop(wal);
    let wal = WalLog::open(dir.join("wal"), 1).unwrap();
    let fresh2 = mini_master(&clock);
    fresh2.restore_chain(&store, v3, 0).unwrap();
    incremental::replay_wal(&fresh2, &wal, 0, from).unwrap();
    assert_eq!(fresh2.snapshot(), reference, "recovery after WAL reopen diverged");

    // Post-recovery training continues and the next delta seals the
    // replayed rows (they were stamped dirty).
    push_grads(&fresh2, 900..910);
    let (dirty, _) = fresh2.dirty_counts(tip.epochs[0]);
    assert!(dirty > 0);

    // Hostile input: corrupting the v3 delta chunk on disk fails the
    // chain restore cleanly (CRC), and a truncated chunk fails decode.
    let chunk_path = dir.join("ckpt/ctr/v0000000003/shard_0.delta");
    let mut bytes = std::fs::read(&chunk_path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&chunk_path, bytes).unwrap();
    let fresh3 = mini_master(&clock);
    assert!(fresh3.restore_chain(&store, v3, 0).is_err());

    std::fs::remove_dir_all(dir).ok();
}

/// Hostile chunk bytes: random truncations and bit flips of a real delta
/// chunk must never panic the decoder — every outcome is Ok or a clean
/// Err (the store's CRC framing catches torn files before this layer;
/// this covers the decoder itself).
#[test]
fn prop_hostile_delta_chunks_fail_cleanly() {
    use weips::util::prop::{check, PairOf, U64Range};
    let clock = ManualClock::new(0);
    let master = mini_master(&clock);
    push_grads(&master, 0..200);
    // Expire a slice so the chunk carries tombstones too.
    clock.advance(10_000);
    push_grads(&master, 0..20);
    assert!(master.expire_features(5_000) > 0);
    let chunk = master.encode_delta(0).bytes;
    let len = chunk.len() as u64;
    check(
        "hostile-delta-chunks",
        &PairOf(U64Range(0, len - 1), U64Range(1, 255)),
        250,
        |(pos, flip)| {
            let target = mini_master(&clock);
            let _ = target.apply_delta(&chunk[..*pos as usize], false);
            let mut mutated = chunk.clone();
            mutated[*pos as usize] ^= *flip as u8;
            let _ = target.apply_delta(&mutated, false);
            let _ = target.apply_delta(&mutated, true);
            Ok(())
        },
    );
}

#[test]
fn checkpoint_versions_rotate_with_gc() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let c = cluster();
    for round in 0..8 {
        for _ in 0..2 {
            c.train_step().unwrap();
        }
        c.flush_sync().unwrap();
        let v = c.checkpoint().unwrap();
        assert_eq!(v, round + 1);
    }
    let versions = c.store.list_versions("ctr");
    // keep=5 local + remote_every=4 replicated survivors.
    assert!(versions.len() >= 5, "{versions:?}");
    assert!(versions.contains(&8));
    assert!(versions.contains(&4), "remote-replicated v4 survives: {versions:?}");
}
