//! Integration: heterogeneous slave types (§1.2.1, §4.1.4b).
//!
//! "The same inference service corresponding to the same model may have
//! different predictions for various business scenarios ... some generate
//! features based on the index input by the user." One master cluster
//! feeds two *different* slave types from the same sync stream:
//!
//! - a ranking slave (ServingWeights transform: every table's `w`);
//! - an embedding slave (EmbeddingOnly transform: only the factor table,
//!   for nearest-neighbour / feature-generation queries).
//!
//! Both consume the identical queue; the transform screens tables per
//! slave type — the paper's "data screening and data conversion".

use std::sync::Arc;
use std::time::Duration;

use weips::config::{ModelKind, ModelSpec};
use weips::proto::{SparsePull, SparsePush};
use weips::queue::Queue;
use weips::runtime::ModelConfig;
use weips::server::master::MasterShard;
use weips::server::slave::SlaveShard;
use weips::sync::{EmbeddingOnly, Gather, Pusher, Router, Scatter, ServingWeights};
use weips::util::clock::ManualClock;

fn spec() -> ModelSpec {
    let cfg = ModelConfig {
        batch_train: 8,
        batch_predict: 2,
        fields: 4,
        dim: 4,
        hidden: 8,
        ftrl_block_rows: 64,
        ftrl_alpha: 0.1,
        ftrl_beta: 1.0,
        ftrl_l1: 0.01,
        ftrl_l2: 1.0,
    };
    ModelSpec::derive("ctr", ModelKind::Fm, &cfg)
}

#[test]
fn one_stream_feeds_ranking_and_embedding_slaves() {
    let spec = spec();
    let clock = Arc::new(ManualClock::new(0));
    let master = Arc::new(MasterShard::new(0, spec.clone(), None, 1, clock.clone()).unwrap());

    // Train some ids on both tables.
    for id in 0..50u64 {
        master
            .sparse_push(&SparsePush {
                model: "ctr".into(),
                table: "w".into(),
                ids: vec![id],
                grads: vec![1.5],
            })
            .unwrap();
        master
            .sparse_push(&SparsePush {
                model: "ctr".into(),
                table: "v".into(),
                ids: vec![id],
                grads: vec![0.5, -0.5, 0.25, -0.25],
            })
            .unwrap();
    }

    // One queue, one gather/pusher.
    let queue = Queue::default();
    let topic = queue.create_topic("sync.ctr", 1).unwrap();
    let mut gather = Gather::new(
        master.clone(),
        weips::config::GatherMode::Realtime,
        clock.clone(),
    );
    let pusher = Pusher::new(topic.clone(), 0);
    pusher.push_all(&gather.flush_now()).unwrap();

    // Ranking slave: serves w of both tables.
    let ftrl_w = spec.optimizer_for("w").unwrap();
    let ftrl_v = spec.optimizer_for("v").unwrap();
    let ranking = Arc::new(SlaveShard::new(
        0,
        0,
        "ctr",
        vec![("w".into(), 1), ("v".into(), 4)],
        vec![("bias".into(), 1)],
        Arc::new(ServingWeights::new(vec![
            ("w".into(), ftrl_w.clone(), 1),
            ("v".into(), ftrl_v.clone(), 4),
        ])),
        Router::new(1),
    ));
    // Embedding slave: screens everything except the factor table.
    let embedding = Arc::new(SlaveShard::new(
        0,
        0,
        "ctr",
        vec![("v".into(), 4)],
        vec![],
        Arc::new(EmbeddingOnly::new("v", ftrl_v, 4)),
        Router::new(1),
    ));

    let mut sc_rank = Scatter::new(topic.clone(), ranking.clone(), 1, 1, clock.clone());
    let mut sc_emb = Scatter::new(topic.clone(), embedding.clone(), 1, 1, clock.clone());
    sc_rank.poll(Duration::ZERO).unwrap();
    sc_emb.poll(Duration::ZERO).unwrap();

    // Ranking slave holds both tables' rows.
    assert_eq!(ranking.total_rows(), 100);
    // Embedding slave screened the w table: only the 50 factor rows.
    assert_eq!(embedding.total_rows(), 50);
    // 50 screened w-entries + the screened dense "bias" snapshot batch.
    assert_eq!(
        embedding.metrics.filtered_entries.load(std::sync::atomic::Ordering::Relaxed),
        51
    );

    // Embedding queries return the factor vector the master trained.
    let master_v = master
        .sparse_pull(&SparsePull {
            model: "ctr".into(),
            table: "v".into(),
            ids: vec![7],
            slot: "w".into(),
        })
        .unwrap();
    let emb_v = embedding
        .sparse_pull(&SparsePull {
            model: "ctr".into(),
            table: "v".into(),
            ids: vec![7],
            slot: "w".into(),
        })
        .unwrap();
    assert_eq!(master_v.values, emb_v.values);
    assert!(emb_v.values.iter().any(|x| *x != 0.0));
    // The w table does not exist on the embedding slave at all.
    assert!(embedding
        .sparse_pull(&SparsePull {
            model: "ctr".into(),
            table: "w".into(),
            ids: vec![7],
            slot: "w".into(),
        })
        .is_err());

    // Deletes propagate to both types from the same stream.
    master.expire_features(0); // no-op (ttl 0)
    {
        // Force-delete id 7 via collector (feature filter path).
        let idx = master.table_index("v").unwrap();
        let mut state_touch = Vec::new();
        master.collector().drain(&mut state_touch); // clear residue
        master.collector().record_deletes(idx, &[7]);
    }
    pusher.push_all(&gather.flush_now()).unwrap();
    sc_rank.poll(Duration::ZERO).unwrap();
    sc_emb.poll(Duration::ZERO).unwrap();
    let gone = embedding
        .sparse_pull(&SparsePull {
            model: "ctr".into(),
            table: "v".into(),
            ids: vec![7],
            slot: "w".into(),
        })
        .unwrap();
    assert!(gone.values.iter().all(|x| *x == 0.0), "embedding row not deleted");
    assert_eq!(ranking.total_rows(), 99);
    assert_eq!(embedding.total_rows(), 49);
}

#[test]
fn full_rows_transform_supports_model_evaluation_slaves() {
    // A model-evaluation slave mirrors full optimizer state (§4.1.4b "can
    // satisfy model evaluation ... or other embedding queries").
    use weips::sync::FullRows;
    let spec = spec();
    let clock = Arc::new(ManualClock::new(0));
    let master = Arc::new(MasterShard::new(0, spec.clone(), None, 1, clock.clone()).unwrap());
    master
        .sparse_push(&SparsePush {
            model: "ctr".into(),
            table: "w".into(),
            ids: vec![1, 2],
            grads: vec![2.0, -2.0],
        })
        .unwrap();

    let queue = Queue::default();
    let topic = queue.create_topic("sync.ctr", 1).unwrap();
    let mut gather =
        Gather::new(master.clone(), weips::config::GatherMode::Realtime, clock.clone());
    let pusher = Pusher::new(topic.clone(), 0);
    pusher.push_all(&gather.flush_now()).unwrap();

    let eval_slave = Arc::new(SlaveShard::new(
        0,
        0,
        "ctr",
        vec![("w".into(), 3)], // full FTRL row width (z, n, w @ dim 1)
        vec![],
        Arc::new(FullRows::new(vec![("w".into(), 3)])),
        Router::new(1),
    ));
    let mut sc = Scatter::new(topic, eval_slave.clone(), 1, 1, clock);
    sc.poll(Duration::ZERO).unwrap();

    // The eval slave sees the complete (z, n, w) row, not just w.
    let full = eval_slave
        .sparse_pull(&SparsePull {
            model: "ctr".into(),
            table: "w".into(),
            ids: vec![1],
            slot: "w".into(),
        })
        .unwrap();
    assert_eq!(full.width, 3);
    let master_row = master
        .sparse_pull(&SparsePull {
            model: "ctr".into(),
            table: "w".into(),
            ids: vec![1],
            slot: "*".into(),
        })
        .unwrap();
    assert_eq!(full.values, master_row.values);
}
