//! Integration: heterogeneous-cluster migration + property tests on
//! coordinator invariants (dynamic routing, §4.2.1d).

use std::sync::Arc;

use weips::config::{ModelKind, ModelSpec};
use weips::proto::{SparsePull, SparsePush};
use weips::runtime::ModelConfig;
use weips::server::master::MasterShard;
use weips::sync::Router;
use weips::util::clock::ManualClock;
use weips::util::prop::{check, PairOf, U64Range, VecOf};

fn spec() -> ModelSpec {
    let cfg = ModelConfig {
        batch_train: 8,
        batch_predict: 2,
        fields: 4,
        dim: 2,
        hidden: 8,
        ftrl_block_rows: 64,
        ftrl_alpha: 0.05,
        ftrl_beta: 1.0,
        ftrl_l1: 1.0,
        ftrl_l2: 1.0,
    };
    ModelSpec::derive("ctr", ModelKind::Fm, &cfg)
}

fn build_cluster(shards: u32) -> Vec<Arc<MasterShard>> {
    let clock = Arc::new(ManualClock::new(0));
    (0..shards)
        .map(|i| Arc::new(MasterShard::new(i, spec(), None, 1, clock.clone()).unwrap()))
        .collect()
}

fn train_ids(cluster: &[Arc<MasterShard>], ids: &[u64]) {
    let router = Router::new(cluster.len() as u32);
    for &id in ids {
        let m = &cluster[router.shard_of(id) as usize];
        m.sparse_push(&SparsePush {
            model: "ctr".into(),
            table: "w".into(),
            ids: vec![id],
            grads: vec![(id % 13) as f32 * 0.3 + 0.5],
        })
        .unwrap();
    }
}

fn migrate(src: &[Arc<MasterShard>], dst: &[Arc<MasterShard>]) -> usize {
    let router = Router::new(dst.len() as u32);
    let mut moved = 0;
    for s in src {
        let snap = s.snapshot();
        for (di, d) in dst.iter().enumerate() {
            moved += d.absorb(&snap, &router, di as u32).unwrap();
        }
    }
    moved
}

#[test]
fn migrate_10_to_20_shards_preserves_everything() {
    let src = build_cluster(10);
    let ids: Vec<u64> = (0..3_000u64).map(|i| i * 7 + 1).collect();
    train_ids(&src, &ids);
    let total_src: usize = src.iter().map(|m| m.total_rows()).sum();
    assert_eq!(total_src, ids.len());

    let dst = build_cluster(20);
    let moved = migrate(&src, &dst);
    assert_eq!(moved, ids.len());
    assert_eq!(dst.iter().map(|m| m.total_rows()).sum::<usize>(), ids.len());

    // Value-level equality through the new routing.
    let src_router = Router::new(10);
    let dst_router = Router::new(20);
    for &id in ids.iter().step_by(37) {
        let a = src[src_router.shard_of(id) as usize]
            .sparse_pull(&SparsePull {
                model: "ctr".into(),
                table: "w".into(),
                ids: vec![id],
                slot: "*".into(),
            })
            .unwrap();
        let b = dst[dst_router.shard_of(id) as usize]
            .sparse_pull(&SparsePull {
                model: "ctr".into(),
                table: "w".into(),
                ids: vec![id],
                slot: "*".into(),
            })
            .unwrap();
        assert_eq!(a, b, "id {id}");
    }
}

#[test]
fn migrate_down_20_to_3_shards() {
    let src = build_cluster(20);
    let ids: Vec<u64> = (0..2_000u64).collect();
    train_ids(&src, &ids);
    let dst = build_cluster(3);
    assert_eq!(migrate(&src, &dst), ids.len());
    // Every id readable at its new home with nonzero state.
    let dst_router = Router::new(3);
    for &id in ids.iter().step_by(101) {
        let v = dst[dst_router.shard_of(id) as usize]
            .sparse_pull(&SparsePull {
                model: "ctr".into(),
                table: "w".into(),
                ids: vec![id],
                slot: "z".into(),
            })
            .unwrap();
        assert!(v.values[0] != 0.0, "id {id} lost state");
    }
}

#[test]
fn prop_migration_is_total_and_exclusive() {
    // For any (src shards, dst shards, ids): after migration every id is
    // owned by exactly one destination shard and no rows are duplicated.
    check(
        "migration-total-exclusive",
        &PairOf(PairOf(U64Range(1, 8), U64Range(1, 8)), VecOf(U64Range(0, 1 << 40), 60)),
        15, // each case builds real shard objects; keep the count modest
        |((s, d), raw_ids)| {
            let mut ids = raw_ids.clone();
            ids.sort();
            ids.dedup();
            let src = build_cluster(*s as u32);
            train_ids(&src, &ids);
            let dst = build_cluster(*d as u32);
            let moved = migrate(&src, &dst);
            if moved != ids.len() {
                return Err(format!("moved {moved} of {}", ids.len()));
            }
            let total: usize = dst.iter().map(|m| m.total_rows()).sum();
            if total != ids.len() {
                return Err(format!("dst holds {total}, want {}", ids.len()));
            }
            // Exclusivity: each id present on exactly its routed shard.
            let router = Router::new(*d as u32);
            for &id in &ids {
                for (i, m) in dst.iter().enumerate() {
                    let has = m
                        .sparse_pull(&SparsePull {
                            model: "ctr".into(),
                            table: "w".into(),
                            ids: vec![id],
                            slot: "z".into(),
                        })
                        .unwrap()
                        .values[0]
                        != 0.0;
                    let should = router.shard_of(id) == i as u32;
                    if has != should {
                        return Err(format!("id {id} on shard {i}: has={has} should={should}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_gather_dedup_is_last_write_wins() {
    // Replaying a dirty-id stream through gather dedup must produce the
    // same final slave state as applying every event in order.
    use weips::sync::collector::{DirtyEvent, DirtyOp};
    use weips::util::hash::FxHashMap;
    use weips::util::prop::Strategy;
    use weips::util::Rng;

    struct Events;
    impl Strategy for Events {
        type Value = Vec<DirtyEvent>;
        fn gen(&self, rng: &mut Rng) -> Self::Value {
            let n = rng.gen_range(200) as usize;
            (0..n)
                .map(|_| DirtyEvent {
                    table: 0,
                    id: rng.gen_range(20),
                    op: if rng.gen_bool(0.8) { DirtyOp::Update } else { DirtyOp::Delete },
                })
                .collect()
        }
    }
    check("gather-lww", &Events, 300, |events| {
        // Sequential truth.
        let mut truth: FxHashMap<u64, DirtyOp> = FxHashMap::default();
        for e in events {
            truth.insert(e.id, e.op);
        }
        // Windowed dedup (what Gather::absorb does).
        let mut window: FxHashMap<u64, DirtyOp> = FxHashMap::default();
        for e in events {
            window.insert(e.id, e.op);
        }
        if window.len() != truth.len() {
            return Err("distinct id sets differ".into());
        }
        for (id, op) in &truth {
            if window.get(id) != Some(op) {
                return Err(format!("id {id}: {op:?} vs {:?}", window.get(id)));
            }
        }
        Ok(())
    });
}
