//! Integration: first-class observability (`/metrics` on every role).
//!
//! The artifact-free tests build the real streaming pipeline (master →
//! gather → queue → scatter → slave) plus a WAL and a router, register
//! everything with the global registry, and scrape it over HTTP exactly
//! like Prometheus would: the exposition must parse, carry the expected
//! role/shard labels, and the push→visible latency histogram must
//! advance after a push/sync/pull round-trip. `docs/METRICS.md` is
//! diffed against the declared series so the reference cannot rot. The
//! `LocalCluster` tests additionally scrape a fully wired cluster and
//! exercise cold-start routing recovery; they skip without AOT
//! artifacts (same gate as the other cluster integration tests).

use std::sync::{Arc, Mutex};
use std::time::Duration;

use weips::config::{GatherMode, ModelKind, ModelSpec};
use weips::metrics::http::{http_get, MetricsServer};
use weips::metrics::{parse_exposition, Sample, DESCRIPTORS};
use weips::optim::{Ftrl, FtrlHyper, Optimizer};
use weips::proto::{SparsePull, SparsePush};
use weips::queue::Queue;
use weips::runtime::ModelConfig;
use weips::server::master::MasterShard;
use weips::server::slave::SlaveShard;
use weips::sync::{Gather, Pusher, Router, Scatter, ServingWeights};
use weips::util::clock::ManualClock;

const GET_TIMEOUT: Duration = Duration::from_secs(5);

/// The registry is process-global and several tests register series
/// under the same labels (WAL, routing); serialize them so a scrape
/// only ever observes the running test's instruments.
static SERIAL: Mutex<()> = Mutex::new(());

fn artifacts_ready() -> bool {
    weips::runtime::default_artifacts_dir().join("manifest.json").exists()
}

fn spec() -> ModelSpec {
    let cfg = ModelConfig {
        batch_train: 8,
        batch_predict: 2,
        fields: 4,
        dim: 2,
        hidden: 8,
        ftrl_block_rows: 64,
        ftrl_alpha: 0.05,
        ftrl_beta: 1.0,
        ftrl_l1: 1.0,
        ftrl_l2: 1.0,
    };
    ModelSpec::derive("ctr", ModelKind::Fm, &cfg)
}

fn slave(shard: u32, replica: u32) -> Arc<SlaveShard> {
    let ftrl: Arc<dyn Optimizer> = Arc::new(Ftrl::new(FtrlHyper::default()));
    Arc::new(SlaveShard::with_stripes(
        shard,
        replica,
        "ctr",
        vec![("w".into(), 1), ("v".into(), 2)],
        vec![("bias".into(), 1)],
        Arc::new(ServingWeights::new(vec![
            ("w".into(), ftrl.clone(), 1),
            ("v".into(), ftrl, 2),
        ])),
        Router::new(1),
        4,
    ))
}

fn scrape(server: &MetricsServer) -> (String, Vec<Sample>) {
    let addr = server.addr().to_string();
    let body = http_get(&addr, "/metrics", GET_TIMEOUT).expect("scrape");
    let samples = parse_exposition(&body).expect("exposition parses");
    (body, samples)
}

fn sample_value(samples: &[Sample], name: &str, labels: &[(&str, &str)]) -> Option<f64> {
    samples
        .iter()
        .find(|s| s.name == name && labels.iter().all(|(k, v)| s.label(k) == Some(*v)))
        .map(|s| s.value)
}

/// CI smoke target: every declared family is exposed (HELP + TYPE) even
/// before any component records a sample, `/healthz` answers, and the
/// whole exposition parses. Runs without artifacts.
#[test]
fn scrape_smoke_serves_every_declared_family() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let server = MetricsServer::serve("127.0.0.1:0").expect("bind metrics");
    let addr = server.addr().to_string();
    assert_eq!(http_get(&addr, "/healthz", GET_TIMEOUT).unwrap(), "ok\n");
    let (body, _samples) = scrape(&server);
    for d in DESCRIPTORS {
        assert!(
            body.contains(&format!("# TYPE {} ", d.name)),
            "family {} missing from the exposition",
            d.name
        );
    }
    // Unknown paths 404 without killing the endpoint.
    assert!(http_get(&addr, "/nope", GET_TIMEOUT).is_err());
    assert_eq!(http_get(&addr, "/healthz", GET_TIMEOUT).unwrap(), "ok\n");
}

/// `docs/METRICS.md` must document exactly the declared series: every
/// backticked `weips_*` family in the doc exists, and every descriptor
/// appears in the doc. Suffix forms (`_bucket`, `_sum`, `_count`) fold
/// onto their histogram family.
#[test]
fn docs_metrics_reference_matches_descriptors() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("repo root")
        .join("docs/METRICS.md");
    let doc = std::fs::read_to_string(&path).expect("docs/METRICS.md");
    let declared: std::collections::BTreeSet<&str> =
        DESCRIPTORS.iter().map(|d| d.name).collect();
    let mut documented = std::collections::BTreeSet::new();
    for part in doc.split('`').skip(1).step_by(2) {
        let name = part.trim();
        let well_formed = name.bytes().all(|b| b.is_ascii_alphanumeric() || b == b'_');
        if !name.starts_with("weips_") || !well_formed {
            continue;
        }
        let family = ["_bucket", "_sum", "_count"]
            .iter()
            .find_map(|suf| {
                let base = name.strip_suffix(suf)?;
                declared.contains(base).then_some(base)
            })
            .unwrap_or(name);
        documented.insert(family.to_string());
    }
    for d in &declared {
        assert!(documented.contains(*d), "series {d} is not documented in docs/METRICS.md");
    }
    for name in &documented {
        assert!(
            declared.contains(name.as_str()),
            "docs/METRICS.md documents unknown series {name}"
        );
    }
}

/// CI smoke target: the alert/journal routes answer with parseable JSON
/// — `/alerts` reports every declared rule after one evaluation, and a
/// journaled event comes back out of `/events`. Runs without artifacts.
#[test]
fn scrape_smoke_alerts_and_events_routes() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let server = MetricsServer::serve("127.0.0.1:0").expect("bind metrics");
    let addr = server.addr().to_string();

    weips::alerts::evaluate("smoke");
    weips::alerts::journal("checkpoint", "smoke_event", "scrape smoke marker", 0);

    let alerts = http_get(&addr, "/alerts", GET_TIMEOUT).expect("GET /alerts");
    let doc = weips::util::json::Json::parse(&alerts).expect("alerts JSON parses");
    let rules = doc.get("rules").and_then(|r| r.as_arr()).expect("rules array");
    assert_eq!(rules.len(), weips::alerts::RULES.len(), "{alerts}");
    for r in rules {
        assert!(r.get("rule").and_then(|v| v.as_str()).is_some(), "{alerts}");
        assert!(r.get("state").and_then(|v| v.as_str()).is_some(), "{alerts}");
    }

    let events = http_get(&addr, "/events", GET_TIMEOUT).expect("GET /events");
    let doc = weips::util::json::Json::parse(&events).expect("events JSON parses");
    let listed = doc.get("events").and_then(|e| e.as_arr()).expect("events array");
    assert!(
        listed.iter().any(|e| e.get("name").and_then(|v| v.as_str()) == Some("smoke_event")),
        "{events}"
    );

    // The alert-state gauges ride the ordinary exposition too.
    let (body, _samples) = scrape(&server);
    assert!(body.contains("weips_alert_state{"), "gauges missing from /metrics");
}

/// `docs/METRICS.md`'s alert-rules table must document exactly the
/// declared `alerts::RULES` — same no-rot discipline as the series
/// reference above: every rule appears with its severity, and no unknown
/// rule is documented.
#[test]
fn docs_metrics_alert_rules_reference_matches_rules() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("repo root")
        .join("docs/METRICS.md");
    let doc = std::fs::read_to_string(&path).expect("docs/METRICS.md");
    let section = doc
        .split("## Alert rules")
        .nth(1)
        .expect("docs/METRICS.md has an '## Alert rules' section");
    let section = section.split("\n## ").next().unwrap();
    let documented: std::collections::BTreeSet<&str> = section
        .lines()
        .filter_map(|l| l.strip_prefix("| `")?.split('`').next())
        .collect();
    for r in weips::alerts::RULES {
        assert!(
            documented.contains(r.name),
            "rule {} is not documented in docs/METRICS.md",
            r.name
        );
        assert!(
            section.contains(&format!("| `{}` | {} |", r.name, r.severity.as_str())),
            "rule {} row must carry severity {}",
            r.name,
            r.severity.as_str()
        );
    }
    for name in &documented {
        assert!(
            weips::alerts::RULES.iter().any(|r| r.name == *name),
            "docs/METRICS.md documents unknown alert rule {name}"
        );
    }
}

/// End-to-end over the real pipeline: master pushes move the master
/// counters and slot heat, the sync round-trip advances the push→visible
/// histogram, and a WAL append surfaces fsync accounting — all read back
/// through an HTTP scrape with the designed labels.
#[test]
fn pipeline_round_trip_moves_labeled_series() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let clock = Arc::new(ManualClock::new(1_000));
    let master =
        Arc::new(MasterShard::with_stripes(7, spec(), None, 1, 4, clock.clone()).unwrap());
    let router = Router::new(1);
    master.set_route_guard(router.clone());
    master.register_metrics("master");
    router.register_metrics("master");
    let serving = slave(0, 3);
    serving.register_metrics("slave");

    let queue = Queue::new(1 << 22);
    let topic = queue.create_topic("sync.ctr", 1).unwrap();
    let mut gather =
        Gather::with_pool(master.clone(), GatherMode::Realtime, clock.clone(), None);
    let pusher = Pusher::new(topic.clone(), 7);
    let mut scatter = Scatter::with_pool(topic, serving.clone(), 1, 1, clock.clone(), None);

    let wal_dir = std::env::temp_dir().join(format!(
        "weips-it-metrics-{}-{:x}",
        std::process::id(),
        weips::util::mono_ns()
    ));
    let wal = weips::queue::WalLog::open_with(&wal_dir, 1, 1).unwrap();

    let server = MetricsServer::serve("127.0.0.1:0").expect("bind metrics");
    let (_, before) = scrape(&server);
    let visible_before = sample_value(
        &before,
        "weips_push_visible_latency_seconds_count",
        &[("role", "slave"), ("shard", "0"), ("replica", "3")],
    )
    .unwrap_or(0.0);

    // Push → gather → queue → scatter → pull round-trip.
    let ids: Vec<u64> = (0..256).collect();
    master
        .sparse_push(&SparsePush {
            model: "ctr".into(),
            table: "w".into(),
            ids: ids.clone(),
            grads: vec![1.0; ids.len()],
        })
        .unwrap();
    clock.advance(25);
    pusher.push_all(&gather.flush_now()).unwrap();
    clock.advance(25);
    while scatter.lag() > 0 {
        scatter.poll(Duration::ZERO).unwrap();
    }
    serving
        .sparse_pull(&SparsePull {
            model: "ctr".into(),
            table: "w".into(),
            ids: ids.clone(),
            slot: "w".into(),
        })
        .unwrap();
    use weips::queue::SyncLog;
    wal.append(0, clock.now_ms(), vec![1, 2, 3]).unwrap();

    let (_, after) = scrape(&server);
    let master_labels = [("role", "master"), ("shard", "7")];
    assert!(sample_value(&after, "weips_master_pushes_total", &master_labels).unwrap() >= 1.0);
    assert!(
        sample_value(&after, "weips_master_push_rows_total", &master_labels).unwrap() >= 256.0
    );
    assert!(sample_value(&after, "weips_master_rows", &master_labels).unwrap() >= 256.0);
    assert!(
        sample_value(
            &after,
            "weips_master_table_rows",
            &[("role", "master"), ("shard", "7"), ("table", "w")],
        )
        .unwrap()
            >= 256.0
    );
    // Slot heat: 256 pushed ids must land in the per-bucket counters.
    let heat: f64 = after
        .iter()
        .filter(|s| s.name == "weips_slot_pushes_total" && s.label("role") == Some("master"))
        .map(|s| s.value)
        .sum();
    assert!(heat >= 256.0, "slot push heat {heat} < 256");
    assert_eq!(
        sample_value(&after, "weips_routing_epoch", &[("role", "master")]).unwrap(),
        0.0
    );
    // Sync pipeline occupancy + freshness.
    let gather_labels = [("role", "master"), ("shard", "7")];
    assert!(
        sample_value(&after, "weips_gather_emitted_entries_total", &gather_labels).unwrap()
            >= 256.0
    );
    let scatter_labels = [("role", "slave"), ("shard", "0"), ("replica", "3")];
    assert!(
        sample_value(&after, "weips_scatter_batches_applied_total", &scatter_labels).unwrap()
            >= 1.0
    );
    let visible_after = sample_value(
        &after,
        "weips_push_visible_latency_seconds_count",
        &scatter_labels,
    )
    .unwrap();
    assert!(
        visible_after > visible_before,
        "push→visible histogram did not advance ({visible_before} -> {visible_after})"
    );
    // 50 simulated ms of latency must land in a bucket whose bound
    // covers it but not in the 1ms bucket.
    let le = |bound: &str| {
        sample_value(
            &after,
            "weips_push_visible_latency_seconds_bucket",
            &[("role", "slave"), ("shard", "0"), ("replica", "3"), ("le", bound)],
        )
        .unwrap()
    };
    assert!(le("1") >= visible_after, "1s bucket must hold every sample");
    assert!(le("0.001") < visible_after, "50ms of latency cannot sit in the 1ms bucket");
    // Slave-side serving + stripe lock accounting.
    assert!(sample_value(&after, "weips_slave_pulls_total", &scatter_labels).unwrap() >= 1.0);
    assert!(
        sample_value(&after, "weips_stripe_lock_acquisitions_total", &scatter_labels).unwrap()
            >= 1.0
    );
    // WAL durability lag: cadence 1 fsyncs every append.
    let wal_labels = [("role", "master")];
    assert!(sample_value(&after, "weips_wal_appends_total", &wal_labels).unwrap() >= 1.0);
    assert!(sample_value(&after, "weips_wal_fsyncs_total", &wal_labels).unwrap() >= 1.0);
    assert!(
        sample_value(&after, "weips_wal_fsync_duration_seconds_count", &wal_labels).unwrap()
            >= 1.0
    );
    drop(wal);
    std::fs::remove_dir_all(&wal_dir).ok();
}

/// Checkpoint manifests seal the live routing (the PR-5 follow-up): a
/// scheduler wired to a router at a bumped epoch writes `route_epoch` +
/// the encoded slot map, and the manifest round-trips both. Runs
/// without artifacts.
#[test]
fn checkpoint_manifest_seals_routing_epoch() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    use weips::meta::MetaStore;
    use weips::scheduler::{CkptPolicy, Scheduler};
    use weips::storage::CheckpointStore;

    let clock = Arc::new(ManualClock::new(0));
    let dir = std::env::temp_dir().join(format!(
        "weips-it-metrics-ckpt-{}-{:x}",
        std::process::id(),
        weips::util::mono_ns()
    ));
    let store = Arc::new(CheckpointStore::new(dir.join("local"), None));
    let scheduler = Scheduler::new(
        MetaStore::new(clock.clone()),
        store.clone(),
        "ctr",
        CkptPolicy::default(),
        clock.clone(),
    );
    let master =
        Arc::new(MasterShard::with_stripes(0, spec(), None, 1, 4, clock.clone()).unwrap());
    let masters = [master];

    // Epoch 0 (uniform map): manifest seals no payload.
    scheduler.set_route_source(Router::with_slots(2, 64));
    let v0 = scheduler.checkpoint_now(&masters, vec![0], 0.5).unwrap();
    let m0 = store.load_manifest("ctr", v0).unwrap();
    assert_eq!((m0.route_epoch, m0.slot_map.len()), (0, 0));

    // Bump the routing, checkpoint again: the sealed map round-trips.
    let router = Router::with_slots(2, 64);
    let mut moved = router.snapshot().as_ref().clone();
    moved.epoch = 9;
    router.install(moved).unwrap();
    scheduler.set_route_source(router.clone());
    let v1 = scheduler.checkpoint_now(&masters, vec![0], 0.5).unwrap();
    let m1 = store.load_manifest("ctr", v1).unwrap();
    assert_eq!(m1.route_epoch, 9);
    let restored = weips::reshard::SlotMap::from_bytes(&m1.slot_map).unwrap();
    assert_eq!(restored.epoch, 9);
    assert_eq!(restored.slots(), 64);
    std::fs::remove_dir_all(&dir).ok();
}

/// Scrape a fully wired `LocalCluster` after real training traffic and
/// verify the aggregated `/cluster` view. Needs AOT artifacts.
#[test]
fn local_cluster_scrape_and_cluster_view() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    use weips::config::ClusterConfig;
    use weips::coordinator::{ClusterOpts, LocalCluster};

    let cluster = LocalCluster::new(ClusterOpts {
        cluster: ClusterConfig {
            model_kind: ModelKind::Lr,
            master_shards: 2,
            slave_shards: 1,
            slave_replicas: 2,
            queue_partitions: 2,
            gather_mode: GatherMode::Realtime,
            ..Default::default()
        },
        ..Default::default()
    })
    .expect("cluster");
    for _ in 0..20 {
        cluster.train_step().unwrap();
        cluster.sync_tick().unwrap();
    }
    cluster.flush_sync().unwrap();
    cluster.checkpoint().unwrap();

    let server = cluster.serve_metrics("127.0.0.1:0").expect("metrics endpoint");
    let (_, samples) = scrape(&server);
    for shard in ["0", "1"] {
        assert!(
            sample_value(
                &samples,
                "weips_master_pushes_total",
                &[("role", "master"), ("shard", shard)],
            )
            .unwrap()
                >= 1.0
        );
    }
    assert!(
        sample_value(&samples, "weips_checkpoints_total", &[("role", "scheduler")]).unwrap()
            >= 1.0
    );
    assert!(
        sample_value(&samples, "weips_model_samples", &[("role", "trainer")]).unwrap() >= 1.0
    );
    let visible: f64 = samples
        .iter()
        .filter(|s| {
            s.name == "weips_push_visible_latency_seconds_count"
                && s.label("role") == Some("slave")
        })
        .map(|s| s.value)
        .sum();
    assert!(visible >= 1.0, "no push→visible samples after training traffic");

    // The aggregated view tags every sample with its instance.
    let self_addr = server.addr().to_string();
    server.set_targets(vec![self_addr.clone()]);
    let merged = http_get(&self_addr, "/cluster", GET_TIMEOUT).expect("cluster view");
    let merged_samples = parse_exposition(&merged).expect("aggregated exposition parses");
    assert!(merged_samples
        .iter()
        .any(|s| s.label("instance") == Some(self_addr.as_str())));
}

/// Cold-start routing recovery (the PR-5 follow-up, end to end): after
/// a live slot migration and a checkpoint, a cluster rebuilt on the
/// same data dir boots at epoch 0 — `recover_master` must restore the
/// sealed slot map from the manifest before purging foreign rows.
/// Needs AOT artifacts.
#[test]
fn cold_start_recovers_routing_from_manifest() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    use weips::config::ClusterConfig;
    use weips::coordinator::{ClusterOpts, LocalCluster};

    let data_dir = std::env::temp_dir().join(format!(
        "weips-it-metrics-cold-{}-{:x}",
        std::process::id(),
        weips::util::mono_ns()
    ));
    let opts = || ClusterOpts {
        cluster: ClusterConfig {
            model_kind: ModelKind::Lr,
            master_shards: 2,
            slave_shards: 1,
            slave_replicas: 1,
            queue_partitions: 2,
            gather_mode: GatherMode::Realtime,
            ..Default::default()
        },
        data_dir: Some(data_dir.clone()),
        ..Default::default()
    };
    let (epoch, version) = {
        let cluster = LocalCluster::new(opts()).expect("cluster");
        for _ in 0..10 {
            cluster.train_step().unwrap();
            cluster.sync_tick().unwrap();
        }
        let map = cluster.master_router.snapshot();
        let slots = weips::reshard::pick_donor_slots(&map, 0, 4).unwrap();
        cluster.migrate_slots(0, 1, &slots).unwrap();
        let epoch = cluster.master_router.epoch();
        assert!(epoch > 0);
        cluster.flush_sync().unwrap();
        let version = cluster.checkpoint().unwrap();
        (epoch, version)
    };
    // Fresh process: router boots at epoch 0, recovery restores it.
    let cluster = LocalCluster::new(opts()).expect("cold cluster");
    assert_eq!(cluster.master_router.epoch(), 0);
    let recovered = cluster.recover_master(0).expect("recover shard 0");
    assert_eq!(recovered, version);
    assert_eq!(cluster.master_router.epoch(), epoch, "sealed slot map not restored");
    std::fs::remove_dir_all(&data_dir).ok();
}

