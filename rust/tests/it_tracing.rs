//! Integration: update-journey tracing over the real sync pipeline.
//!
//! A sampled push travels gather → queue → scatter and must leave one
//! complete span chain (≥ 6 declared stages) retrievable over
//! `GET /trace/<id>`, with stage durations bounded by the pipeline's
//! wall-clock drive time. With tracing off, on, or sampled the bytes on
//! the queue must be identical — the trace context is derived from
//! envelope fields, never carried on the wire. Finally the `/healthz`
//! readiness endpoint must flip to `degraded` when scatter lag exceeds
//! its configured bound.
//!
//! The trace sink and health registry are process globals, so every test
//! here serialises on one file-local lock (the lib's `test_lock` is
//! `#[cfg(test)]`-only and invisible to integration binaries).

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

use weips::config::{GatherMode, ModelKind, ModelSpec};
use weips::metrics::http::{http_get, MetricsServer};
use weips::optim::{Ftrl, FtrlHyper, Optimizer};
use weips::proto::{SparsePush, SyncBatch};
use weips::queue::Queue;
use weips::runtime::ModelConfig;
use weips::server::master::MasterShard;
use weips::server::slave::SlaveShard;
use weips::sync::{Gather, Pusher, Router, Scatter, ServingWeights};
use weips::trace;
use weips::util::clock::ManualClock;

fn lock() -> &'static Mutex<()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
}

fn spec() -> ModelSpec {
    let cfg = ModelConfig {
        batch_train: 8,
        batch_predict: 2,
        fields: 4,
        dim: 2,
        hidden: 8,
        ftrl_block_rows: 64,
        ftrl_alpha: 0.05,
        ftrl_beta: 1.0,
        ftrl_l1: 1.0,
        ftrl_l2: 1.0,
    };
    ModelSpec::derive("ctr", ModelKind::Fm, &cfg)
}

fn slave(stripes: usize) -> Arc<SlaveShard> {
    let ftrl: Arc<dyn Optimizer> = Arc::new(Ftrl::new(FtrlHyper::default()));
    Arc::new(SlaveShard::with_stripes(
        0,
        0,
        "ctr",
        vec![("w".into(), 1), ("v".into(), 2)],
        vec![("bias".into(), 1)],
        Arc::new(ServingWeights::new(vec![
            ("w".into(), ftrl.clone(), 1),
            ("v".into(), ftrl, 2),
        ])),
        Router::new(1),
        stripes,
    ))
}

struct Pipeline {
    clock: Arc<ManualClock>,
    master: Arc<MasterShard>,
    gather: Gather,
    pusher: Pusher,
    scatter: Scatter,
}

fn pipeline() -> Pipeline {
    let clock = Arc::new(ManualClock::new(0));
    let master =
        Arc::new(MasterShard::with_stripes(0, spec(), None, 1, 8, clock.clone()).unwrap());
    let queue = Queue::new(1 << 26);
    let topic = queue.create_topic("sync.ctr", 1).unwrap();
    let gather = Gather::with_pool(
        master.clone(),
        GatherMode::Threshold(1_000_000),
        clock.clone(),
        None,
    );
    let pusher = Pusher::new(topic.clone(), 0);
    let scatter = Scatter::with_pool(topic, slave(8), 1, 1, clock.clone(), None);
    Pipeline { clock, master, gather, pusher, scatter }
}

fn push_rounds(master: &MasterShard, rounds: u64) {
    for round in 0..rounds {
        let ids: Vec<u64> = (0..300).map(|i| (i * 13 + round) % 900).collect();
        let grads = vec![1.5f32; ids.len()];
        master
            .sparse_push(&SparsePush { model: "ctr".into(), table: "w".into(), ids, grads })
            .unwrap();
    }
}

#[test]
fn sampled_push_yields_a_complete_retrievable_span_chain() {
    let _g = lock().lock().unwrap();
    trace::configure(1);
    trace::clear();

    let mut p = pipeline();
    let drive_start = weips::util::mono_ns();
    push_rounds(&p.master, 3);
    p.clock.advance(25);
    let batches: Vec<SyncBatch> = p.gather.flush_now();
    let sparse = batches.iter().find(|b| b.table == "w").expect("no sparse batch emitted");
    let id = trace::trace_id(&sparse.model, &sparse.table, sparse.shard, sparse.seq);
    let created_ms = sparse.created_ms;
    p.pusher.push_all(&batches).unwrap();
    p.clock.advance(25);
    p.scatter.poll(Duration::ZERO).unwrap();
    let drive_ns = weips::util::mono_ns().saturating_sub(drive_start);

    // One chain, ≥ 6 distinct declared stages, all tied to this batch.
    let spans = trace::spans_for(id);
    let mut stages: Vec<&str> = spans.iter().map(|s| s.stage).collect();
    stages.sort_unstable();
    stages.dedup();
    assert!(
        stages.len() >= 6,
        "expected >= 6 distinct stages, got {}: {stages:?}",
        stages.len()
    );
    let expected = [
        "collector_drain",
        "gather_emit",
        "queue_append",
        "scatter_decode",
        "scatter_apply",
        "cache_invalidate",
    ];
    for want in expected {
        assert!(stages.contains(&want), "missing stage {want}: {stages:?}");
    }
    for s in &spans {
        assert_eq!(s.trace_id, id);
        assert_eq!(s.seq, sparse.seq);
        assert_eq!(s.origin_ms, created_ms);
    }

    // Stage starts follow the declared pipeline order, and the summed
    // stage time is bounded by the wall clock spent driving the pipeline
    // (the push→visible latency as the histogram would observe it, plus
    // the pre-flush push phase).
    let mut ordered: Vec<&weips::trace::Span> = spans.iter().collect();
    ordered.sort_by_key(|s| (trace::stage_index(s.stage), s.start_ns));
    for pair in ordered.windows(2) {
        assert!(
            pair[0].start_ns <= pair[1].start_ns,
            "stage {} started after {}",
            pair[0].stage,
            pair[1].stage
        );
    }
    let stage_sum_ns: u64 = spans.iter().map(|s| s.dur_ns).sum();
    assert!(stage_sum_ns > 0, "stage durations all zero");
    assert!(
        stage_sum_ns <= drive_ns,
        "stage sum {stage_sum_ns}ns exceeds pipeline wall time {drive_ns}ns"
    );

    // The scatter observed the manual-clock push→visible latency (50ms
    // advanced between push and apply, 25ms of it after batch creation).
    assert!(p.scatter.stats.latency_ms.count() >= 1);
    assert!(p.scatter.stats.latency_ms.max() <= 50);

    // The chain is retrievable over HTTP, both in the recent index and
    // by id; unknown ids 404.
    let server = MetricsServer::serve("127.0.0.1:0").unwrap();
    let addr = server.addr().to_string();
    let index = http_get(&addr, "/trace", Duration::from_secs(2)).unwrap();
    assert!(index.contains(&trace::format_id(id)), "trace index missing chain: {index}");
    let chain =
        http_get(&addr, &format!("/trace/{}", trace::format_id(id)), Duration::from_secs(2))
            .unwrap();
    for want in ["collector_drain", "gather_emit", "queue_append", "scatter_apply"] {
        assert!(chain.contains(want), "chain body missing {want}: {chain}");
    }
    assert!(http_get(&addr, "/trace/ffffffffffffffff", Duration::from_secs(2)).is_err());

    trace::configure(0);
    trace::clear();
}

#[test]
fn sync_bytes_are_identical_with_tracing_off_on_and_sampled() {
    let _g = lock().lock().unwrap();

    // The trace context is derived from envelope fields already on the
    // wire, so the queued bytes must not change with the sample rate.
    let run = |sample_every: u64| -> Vec<Vec<u8>> {
        trace::configure(sample_every);
        trace::clear();
        let clock = Arc::new(ManualClock::new(0));
        let master =
            Arc::new(MasterShard::with_stripes(0, spec(), None, 1, 8, clock.clone()).unwrap());
        let queue = Queue::new(1 << 26);
        let topic = queue.create_topic("sync.ctr", 1).unwrap();
        let mut gather = Gather::with_pool(
            master.clone(),
            GatherMode::Threshold(1_000_000),
            clock.clone(),
            None,
        );
        let pusher = Pusher::new(topic.clone(), 0);
        push_rounds(&master, 5);
        clock.advance(7);
        pusher.push_all(&gather.flush_now()).unwrap();
        topic
            .partition(0)
            .unwrap()
            .fetch(0, 4096, Duration::ZERO)
            .unwrap()
            .into_iter()
            .map(|r| r.payload.as_ref().clone())
            .collect()
    };

    let off = run(0);
    let every = run(1);
    let sampled = run(7);
    assert!(!off.is_empty(), "workload produced no sync records");
    assert_eq!(off, every, "queued bytes changed with tracing on");
    assert_eq!(off, sampled, "queued bytes changed with sampled tracing");

    trace::configure(0);
    trace::clear();
}

#[test]
fn healthz_flips_to_degraded_when_scatter_lag_exceeds_its_bound() {
    let _g = lock().lock().unwrap();
    trace::configure(0);

    // The scatter registers a scatter_lag_records readiness probe at
    // construction; a bound plus an excessive lag must degrade /healthz
    // with a reason, and recovery must restore plain `ok`.
    let p = pipeline();
    weips::metrics::set_health_bound("scatter_lag_records", Some(1_000.0));
    p.scatter.stats.lag_records.store(5_000_000, Ordering::Relaxed);

    let server = MetricsServer::serve("127.0.0.1:0").unwrap();
    let addr = server.addr().to_string();
    let degraded = http_get(&addr, "/healthz", Duration::from_secs(2)).unwrap();
    assert!(degraded.starts_with("degraded"), "expected degraded, got: {degraded}");
    assert!(degraded.contains("scatter lag"), "missing reason: {degraded}");

    p.scatter.stats.lag_records.store(0, Ordering::Relaxed);
    let ok = http_get(&addr, "/healthz", Duration::from_secs(2)).unwrap();
    assert_eq!(ok.trim(), "ok");

    weips::metrics::set_health_bound("scatter_lag_records", None);
}
