//! Integration: the cluster health engine over a real pipeline.
//!
//! A stalled scatter consumer must walk `scatter_lag_high` through the
//! declared pending → firing → resolved lifecycle, visible over
//! `GET /alerts` and journaled as structured events over `GET /events`.
//! A corrupted model must fire the `window_auc_low` rule and trip the
//! domino downgrade, and the rollback must land in the journal carrying
//! the rule's name — the acceptance loop: rule evaluation → Domino
//! trigger → downgrade action → `/events` entry. Finally the evaluator
//! is read-only against the data path: sync-batch wire bytes must be
//! identical with the evaluator off and ticking.
//!
//! The alert engine and journal are process globals, so every test here
//! serialises on one file-local lock (the lib's `test_lock` is
//! `#[cfg(test)]`-only and invisible to integration binaries).

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

use weips::alerts;
use weips::config::{ClusterConfig, GatherMode, ModelKind, ModelSpec};
use weips::coordinator::{ClusterOpts, LocalCluster};
use weips::downgrade::SwitchStrategy;
use weips::metrics::http::{http_get, MetricsServer};
use weips::optim::{Ftrl, FtrlHyper, Optimizer};
use weips::proto::SparsePush;
use weips::queue::Queue;
use weips::runtime::ModelConfig;
use weips::sample::WorkloadConfig;
use weips::server::master::MasterShard;
use weips::server::slave::SlaveShard;
use weips::sync::{Gather, Pusher, Router, Scatter, ServingWeights};
use weips::util::clock::ManualClock;
use weips::util::json::Json;

fn lock() -> &'static Mutex<()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
}

fn artifacts_ready() -> bool {
    weips::runtime::default_artifacts_dir().join("manifest.json").exists()
}

fn spec() -> ModelSpec {
    let cfg = ModelConfig {
        batch_train: 8,
        batch_predict: 2,
        fields: 4,
        dim: 2,
        hidden: 8,
        ftrl_block_rows: 64,
        ftrl_alpha: 0.05,
        ftrl_beta: 1.0,
        ftrl_l1: 1.0,
        ftrl_l2: 1.0,
    };
    ModelSpec::derive("ctr", ModelKind::Fm, &cfg)
}

fn slave() -> Arc<SlaveShard> {
    let ftrl: Arc<dyn Optimizer> = Arc::new(Ftrl::new(FtrlHyper::default()));
    Arc::new(SlaveShard::with_stripes(
        0,
        0,
        "ctr",
        vec![("w".into(), 1), ("v".into(), 2)],
        vec![("bias".into(), 1)],
        Arc::new(ServingWeights::new(vec![
            ("w".into(), ftrl.clone(), 1),
            ("v".into(), ftrl, 2),
        ])),
        Router::new(1),
        8,
    ))
}

/// A scatter consumer on an empty topic: construction registers the
/// `scatter_lag_records` alerts source, which is all the lifecycle test
/// needs to drive.
fn scatter_only() -> Scatter {
    let clock = Arc::new(ManualClock::new(0));
    let queue = Queue::new(1 << 26);
    let topic = queue.create_topic("sync.ctr", 1).unwrap();
    Scatter::with_pool(topic, slave(), 1, 1, clock, None)
}

fn state_of(statuses: &[alerts::RuleStatus], rule: &str) -> alerts::State {
    statuses.iter().find(|s| s.rule == rule).expect("rule declared").state
}

/// Stalled scatter consumer → `scatter_lag_high` walks ok → pending →
/// firing (with `for`-duration hysteresis) → resolved, each transition
/// journaled and the terminal states visible over HTTP.
#[test]
fn scatter_lag_alert_walks_pending_firing_resolved_over_http() {
    let _g = lock().lock().unwrap_or_else(|e| e.into_inner());
    alerts::clear();

    // Scatter construction registers the `scatter_lag_records` source
    // (shared with the /healthz readiness probe); a stalled consumer is
    // simulated by pinning its lag counter past the declared 1e6 bound.
    let scatter = scatter_only();
    scatter.stats.lag_records.store(5_000_000, Ordering::Relaxed);

    // for_ticks = 2: two breaching evaluations stay pending, the third
    // crosses the hysteresis window and fires.
    assert_eq!(state_of(&alerts::evaluate("it"), "scatter_lag_high"), alerts::State::Pending);
    assert_eq!(state_of(&alerts::evaluate("it"), "scatter_lag_high"), alerts::State::Pending);
    assert_eq!(state_of(&alerts::evaluate("it"), "scatter_lag_high"), alerts::State::Firing);

    // The firing state is served over /alerts (snapshot of the last
    // evaluation) and the gauge is exported on /metrics.
    let server = MetricsServer::serve("127.0.0.1:0").unwrap();
    let addr = server.addr().to_string();
    let body = http_get(&addr, "/alerts", Duration::from_secs(2)).unwrap();
    let parsed = Json::parse(&body).expect("/alerts is JSON");
    let rules = parsed.get("rules").and_then(|r| r.as_arr()).expect("rules array");
    assert_eq!(rules.len(), alerts::RULES.len());
    let lag = rules
        .iter()
        .find(|r| r.get("rule").and_then(|v| v.as_str()) == Some("scatter_lag_high"))
        .expect("scatter_lag_high in /alerts");
    assert_eq!(lag.get("state").and_then(|v| v.as_str()), Some("firing"));
    assert_eq!(lag.get("severity").and_then(|v| v.as_str()), Some("warning"));
    let scrape = http_get(&addr, "/metrics", Duration::from_secs(2)).unwrap();
    assert!(
        scrape.contains("weips_alert_state{rule=\"scatter_lag_high\""),
        "alert-state gauge missing from scrape"
    );

    // Recovery resolves the alert on the next evaluation.
    scatter.stats.lag_records.store(0, Ordering::Relaxed);
    assert_eq!(state_of(&alerts::evaluate("it"), "scatter_lag_high"), alerts::State::Ok);

    // Every transition was journaled with the rule's name, and the
    // journal is served over /events.
    let events = http_get(&addr, "/events", Duration::from_secs(2)).unwrap();
    for kind in ["alert_pending", "alert_firing", "alert_resolved"] {
        assert!(
            events.contains(&format!("\"kind\":\"{kind}\",\"name\":\"scatter_lag_high\"")),
            "missing {kind} transition in /events: {events}"
        );
    }

    alerts::clear();
}

/// The acceptance loop (§4.3 + tentpole): corrupt the model, let the
/// declared `window_auc_low` rule fire, let the domino act on the same
/// quality dip, and find the rollback in the event journal carrying the
/// rule's name.
#[test]
fn domino_downgrade_is_triggered_by_rule_and_journaled() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let _g = lock().lock().unwrap_or_else(|e| e.into_inner());
    alerts::clear();

    // LocalCluster::new pins the window_auc_low rule bound to the domino
    // trigger threshold: one knob, two consumers.
    let c = LocalCluster::new(ClusterOpts {
        cluster: ClusterConfig {
            model_kind: ModelKind::Lr,
            master_shards: 2,
            slave_shards: 1,
            slave_replicas: 2,
            queue_partitions: 2,
            gather_mode: GatherMode::Realtime,
            ..Default::default()
        },
        workload: WorkloadConfig {
            ids_per_field: 300,
            zipf_s: 1.3,
            seed: 5,
            ..Default::default()
        },
        trigger_threshold: 0.52,
        trigger_smooth: 3,
        switch_strategy: SwitchStrategy::LatestStable,
        ..Default::default()
    })
    .expect("cluster");

    for _ in 0..120 {
        c.train_step().unwrap();
        c.sync_tick().unwrap();
    }
    c.flush_sync().unwrap();
    assert!(c.monitor.snapshot().window_auc > 0.54, "model failed to learn");
    let stable = c.checkpoint().unwrap();
    assert!(
        alerts::recent_events(64).iter().any(|e| e.kind == "checkpoint"),
        "checkpoint lifecycle missing from the journal"
    );

    c.corrupt_model().unwrap();
    c.flush_sync().unwrap();

    // Control ticks evaluate the declared rules and the smoothed domino
    // trigger against the same collapsing window AUC.
    let mut fired = None;
    for _ in 0..60 {
        c.train_step().unwrap();
        c.sync_tick().unwrap();
        if let Some(plan) = c.control_tick().unwrap() {
            fired = Some(plan);
            break;
        }
    }
    let plan = fired.expect("domino trigger never fired on corrupted model");
    assert_eq!(plan.target_version, stable);
    assert_eq!(c.vm.current(), stable);

    // The declared rule fired (for_ticks = 0: first breaching evaluation
    // is already firing) before/with the smoothed domino...
    let events = alerts::recent_events(256);
    assert!(
        events.iter().any(|e| e.kind == "alert_firing" && e.name == "window_auc_low"),
        "window_auc_low never journaled a firing transition"
    );
    // ...and the downgrade itself was journaled carrying the rule name.
    let domino = events
        .iter()
        .find(|e| e.kind == "degradation" && e.name == "window_auc_low")
        .expect("domino downgrade missing from the journal");
    assert!(
        domino.detail.contains(&format!("v{} -> v{}", plan.from_version, plan.target_version)),
        "journal detail does not cite the rollback versions: {}",
        domino.detail
    );
    assert!(
        events.iter().any(|e| e.kind == "degradation" && e.name == "serving_cache_clear"),
        "rollback cache clear missing from the journal"
    );

    // The same loop is observable over HTTP.
    let server = MetricsServer::serve("127.0.0.1:0").unwrap();
    let addr = server.addr().to_string();
    let body = http_get(&addr, "/events", Duration::from_secs(2)).unwrap();
    assert!(
        body.contains("\"kind\":\"degradation\",\"name\":\"window_auc_low\""),
        "/events missing the domino degradation entry: {body}"
    );

    alerts::clear();
}

/// The evaluator only reads registry state: the bytes on the sync queue
/// must be identical with the evaluator off and ticking aggressively.
#[test]
fn sync_bytes_are_identical_with_evaluator_off_and_ticking() {
    let _g = lock().lock().unwrap_or_else(|e| e.into_inner());

    let run = |tick_ms: u64| -> Vec<Vec<u8>> {
        alerts::clear();
        let _ticker = alerts::spawn_ticker("it", tick_ms);
        let clock = Arc::new(ManualClock::new(0));
        let master =
            Arc::new(MasterShard::with_stripes(0, spec(), None, 1, 8, clock.clone()).unwrap());
        let queue = Queue::new(1 << 26);
        let topic = queue.create_topic("sync.ctr", 1).unwrap();
        let mut gather = Gather::with_pool(
            master.clone(),
            GatherMode::Threshold(1_000_000),
            clock.clone(),
            None,
        );
        let pusher = Pusher::new(topic.clone(), 0);
        for round in 0..5u64 {
            let ids: Vec<u64> = (0..300).map(|i| (i * 13 + round) % 900).collect();
            let grads = vec![1.5f32; ids.len()];
            master
                .sparse_push(&SparsePush { model: "ctr".into(), table: "w".into(), ids, grads })
                .unwrap();
            // Give the ticker real windows to race the push phase.
            if tick_ms > 0 {
                std::thread::sleep(Duration::from_millis(2));
            }
        }
        clock.advance(7);
        pusher.push_all(&gather.flush_now()).unwrap();
        topic
            .partition(0)
            .unwrap()
            .fetch(0, 4096, Duration::ZERO)
            .unwrap()
            .into_iter()
            .map(|r| r.payload.as_ref().clone())
            .collect()
    };

    let off = run(0);
    let ticking = run(1);
    assert!(!off.is_empty(), "workload produced no sync records");
    assert_eq!(off, ticking, "queued bytes changed with the evaluator ticking");

    alerts::clear();
}
