//! Integration: slot-based elastic resharding with live migration.
//!
//! A 4-master / 2-slave pipeline (manual assembly, no AOT artifacts) runs
//! concurrent trainer pushes through a shared slot router while the main
//! thread migrates **all of shard 3's slots** (1/4 of the universe) to
//! shard 1 — base copy, dirty-epoch catch-up, sealed hand-off, epoch-bump
//! cutover. Afterwards the logical model state (values *and* row
//! metadata, i.e. update counts) must be **byte-identical** to a control
//! cluster that ran the same deterministic event streams with no
//! migration, on masters and on slaves — zero lost, duplicated or
//! misrouted updates. A property test proves slot-map rebalances are
//! minimal-disruption: only ids in moved slots ever change owners.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use weips::config::{GatherMode, ModelKind, ModelSpec};
use weips::net::Channel;
use weips::optim::{Ftrl, FtrlHyper, Optimizer};
use weips::proto::SparsePull;
use weips::queue::{Queue, Topic};
use weips::reshard::{balance_moves, MigrationOpts, SlotMap, SlotSet, SlotTransfer};
use weips::runtime::ModelConfig;
use weips::server::master::{MasterService, MasterShard};
use weips::server::slave::SlaveShard;
use weips::sync::router::partition_of_shard;
use weips::sync::{Gather, Pusher, Router, Scatter, ServingWeights};
use weips::table::DeltaRow;
use weips::util::clock::ManualClock;
use weips::util::prop::{check, PairOf, U64Range, VecOf};
use weips::worker::ShardedClient;

const UNIVERSE: usize = 64;
const MASTERS: u32 = 4;
const SLAVES: u32 = 2;
const IDS: u64 = 1024;
const ROUNDS: u64 = 40;

fn spec() -> ModelSpec {
    let cfg = ModelConfig {
        batch_train: 8,
        batch_predict: 2,
        fields: 4,
        dim: 2,
        hidden: 8,
        ftrl_block_rows: 64,
        ftrl_alpha: 0.05,
        ftrl_beta: 1.0,
        ftrl_l1: 1.0,
        ftrl_l2: 1.0,
    };
    ModelSpec::derive("ctr", ModelKind::Fm, &cfg)
}

struct TestCluster {
    _queue: Queue,
    topic: Arc<Topic>,
    router: Router,
    masters: Vec<Arc<MasterShard>>,
    gathers: Vec<Arc<Mutex<Gather>>>,
    pushers: Vec<Arc<Pusher>>,
    slaves: Vec<Arc<SlaveShard>>,
    scatters: Vec<Arc<Mutex<Scatter>>>,
    client: Arc<ShardedClient>,
}

fn build() -> TestCluster {
    let clock = Arc::new(ManualClock::new(0));
    let queue = Queue::new(1 << 26);
    let topic = queue.create_topic("sync.ctr", MASTERS as usize).unwrap();
    let router = Router::with_slots(MASTERS, UNIVERSE);

    let mut masters = Vec::new();
    let mut gathers = Vec::new();
    let mut pushers = Vec::new();
    for i in 0..MASTERS {
        let m = Arc::new(MasterShard::with_stripes(i, spec(), None, 1, 4, clock.clone()).unwrap());
        m.set_route_guard(router.clone());
        gathers.push(Arc::new(Mutex::new(Gather::new(
            m.clone(),
            GatherMode::Threshold(256),
            clock.clone(),
        ))));
        pushers.push(Arc::new(Pusher::new(topic.clone(), i)));
        masters.push(m);
    }

    let ftrl: Arc<dyn Optimizer> = Arc::new(Ftrl::new(FtrlHyper::default()));
    let transform = Arc::new(ServingWeights::new(vec![
        ("w".into(), ftrl.clone(), 1),
        ("v".into(), ftrl, 2),
    ]));
    let slave_router = Router::with_slots(SLAVES, UNIVERSE);
    let mut slaves = Vec::new();
    let mut scatters = Vec::new();
    for s in 0..SLAVES {
        let shard = Arc::new(SlaveShard::with_stripes(
            s,
            0,
            "ctr",
            vec![("w".into(), 1), ("v".into(), 2)],
            vec![("bias".into(), 1)],
            transform.clone(),
            slave_router.clone(),
            4,
        ));
        scatters.push(Arc::new(Mutex::new(Scatter::new(
            topic.clone(),
            shard.clone(),
            MASTERS,
            SLAVES,
            clock.clone(),
        ))));
        slaves.push(shard);
    }

    let channels: Vec<Channel> = masters
        .iter()
        .map(|m| Channel::local(Arc::new(MasterService { shard: m.clone(), store: None })))
        .collect();
    let client = Arc::new(ShardedClient::with_router("ctr", channels, router.clone()));

    TestCluster {
        _queue: queue,
        topic,
        router,
        masters,
        gathers,
        pushers,
        slaves,
        scatters,
        client,
    }
}

/// Flush every pending window and drain the queue dry.
fn flush_all(c: &TestCluster) {
    for (g, p) in c.gathers.iter().zip(&c.pushers) {
        let mut g = g.lock().unwrap();
        let batches = g.flush_now();
        p.push_all(&batches).unwrap();
    }
    loop {
        let mut lag = 0;
        for sc in &c.scatters {
            let mut sc = sc.lock().unwrap();
            sc.poll(Duration::ZERO).unwrap();
            lag += sc.lag();
        }
        if lag == 0 {
            return;
        }
    }
}

/// Run the deterministic trainer streams: 4 threads over disjoint id
/// ranges (per-id gradient sequences are identical regardless of thread
/// interleaving), with the sync pump running concurrently. `migrate`
/// runs on the caller thread while the traffic flows.
fn run_traffic(c: &TestCluster, migrate: impl FnOnce(&TestCluster)) {
    let stop = Arc::new(AtomicBool::new(false));
    let pump = {
        let stop = stop.clone();
        let gathers = c.gathers.clone();
        let pushers = c.pushers.clone();
        let scatters = c.scatters.clone();
        std::thread::spawn(move || {
            while !stop.load(Ordering::Acquire) {
                for (g, p) in gathers.iter().zip(&pushers) {
                    // Gather lock held across the push: the migration
                    // thread's donor flush must not interleave with an
                    // already-polled older window.
                    let mut g = g.lock().unwrap();
                    let batches = g.poll();
                    p.push_all(&batches).unwrap();
                }
                for sc in &scatters {
                    sc.lock().unwrap().poll(Duration::ZERO).unwrap();
                }
            }
        })
    };
    let mut workers = Vec::new();
    for t in 0..4u64 {
        let client = c.client.clone();
        workers.push(std::thread::spawn(move || {
            let per = IDS / 4;
            let ids: Vec<u64> = (t * per..(t + 1) * per).collect();
            for round in 0..ROUNDS {
                let grad = 0.5 + t as f32 * 0.1 + round as f32 * 0.01;
                let grads = vec![grad; ids.len()];
                client.sparse_push("w", &ids, &grads).unwrap();
            }
        }));
    }
    migrate(c);
    for w in workers {
        w.join().unwrap();
    }
    stop.store(true, Ordering::Release);
    pump.join().unwrap();
    flush_all(c);
}

/// The logical model: every row of every shard, unioned and sorted by id
/// per table — values *and* metadata (update counts), so equality means
/// zero lost and zero duplicated updates.
fn logical_state(c: &TestCluster) -> Vec<Vec<DeltaRow>> {
    let full = SlotSet::full(UNIVERSE);
    let mut per_table: Vec<Vec<DeltaRow>> = vec![Vec::new(); 2];
    for m in &c.masters {
        for (ti, (_, rows, dels)) in m.collect_slot_delta(None, &full).into_iter().enumerate() {
            assert!(dels.is_empty());
            per_table[ti].extend(rows);
        }
    }
    for rows in &mut per_table {
        rows.sort_by_key(|r| r.id);
    }
    per_table
}

#[test]
fn live_migration_is_byte_identical_to_control() {
    let control = build();
    run_traffic(&control, |_| {});

    let live = build();
    let map = live.router.snapshot();
    let moved = map.slots_of(3); // every slot of shard 3 = 1/4 of the universe
    assert!(moved.len() * 4 >= UNIVERSE, "moving less than 1/4 of the slots");
    run_traffic(&live, |c| {
        // 1. Widen subscriptions before any routing change.
        for sc in &c.scatters {
            sc.lock().unwrap().subscribe_all().unwrap();
        }
        // 2. Online copy + catch-up while pushers hammer the donor.
        // Recipient 0 on purpose: moved ids are served by slave 1 (odd
        // slots), whose reduced subset {1, 3} does NOT cover partition 0
        // — post-cutover updates reach it only through the widened
        // subscription, so this run proves the widening is load-bearing.
        let mut t =
            SlotTransfer::new(&c.masters[3], &c.masters[0], &moved, UNIVERSE).unwrap();
        t.run_catchup(&MigrationOpts::default()).unwrap();
        // 3. Hand-off window.
        t.seal().unwrap();
        t.final_sync().unwrap();
        // 4. Flush the donor's sync window (gather lock held across the
        // push so the pump cannot interleave), drain consumers past it.
        {
            let mut g = c.gathers[3].lock().unwrap();
            let batches = g.flush_now();
            c.pushers[3].push_all(&batches).unwrap();
        }
        let donor_p = partition_of_shard(3, MASTERS);
        let target = c.topic.partition(donor_p as usize).unwrap().latest_offset();
        loop {
            let mut behind = false;
            for sc in &c.scatters {
                let mut sc = sc.lock().unwrap();
                sc.poll(Duration::ZERO).unwrap();
                match sc.offset_for(donor_p) {
                    Some(o) if o >= target => {}
                    _ => behind = true,
                }
            }
            if !behind {
                break;
            }
            std::thread::sleep(Duration::from_micros(200));
        }
        // 5. Cutover: the epoch bump re-routes the live pushers.
        let bumped = map
            .rebalanced(&moved.iter().map(|&s| (s, 0)).collect::<Vec<_>>())
            .unwrap();
        c.router.install(bumped).unwrap();
        // 6. Release the donor.
        let report = t.finish().unwrap();
        assert!(report.base_rows > 0, "base pass moved nothing");
        assert!(report.purged_rows > 0, "donor kept the moved rows");
    });

    // The donor owned exactly the moved slots: it must now be empty.
    assert_eq!(live.masters[3].total_rows(), 0, "donor still holds moved rows");
    assert_eq!(live.router.epoch(), 1);

    // Master state: byte-identical to the no-migration control (values
    // and update counts — zero lost, duplicated or misrouted updates).
    let control_state = logical_state(&control);
    let live_state = logical_state(&live);
    assert_eq!(control_state[0].len(), live_state[0].len(), "row count diverged");
    assert_eq!(control_state, live_state, "migrated state != control state");
    assert_eq!(control_state[0].len() as u64, IDS);
    // Every update round-tripped: per-id update counts sum to the pushes.
    let total_updates: u64 = live_state[0].iter().map(|r| r.updates as u64).sum();
    assert_eq!(total_updates, IDS * ROUNDS, "lost or duplicated updates");

    // Ownership exclusivity under the bumped map.
    let bumped = live.router.snapshot();
    for row in &live_state[0] {
        let owner = bumped.shard_of(row.id);
        assert_ne!(owner, 3, "id {} still routed to the drained donor", row.id);
        let probe = live.masters[owner as usize].collect_slot_delta(
            None,
            &SlotSet::from_slots(&[bumped.slot_of(row.id)], UNIVERSE).unwrap(),
        );
        assert!(
            probe[0].1.iter().any(|r| r.id == row.id),
            "id {} not on its owner {owner}",
            row.id
        );
    }

    // Slave serving state matches the control byte for byte.
    let all_ids: Vec<u64> = (0..IDS).collect();
    for s in 0..SLAVES as usize {
        let pull = |c: &TestCluster| {
            c.slaves[s]
                .sparse_pull(&SparsePull {
                    model: "ctr".into(),
                    table: "w".into(),
                    ids: all_ids.clone(),
                    slot: "w".into(),
                })
                .unwrap()
        };
        assert_eq!(pull(&control), pull(&live), "slave {s} serving state diverged");
        assert_eq!(control.slaves[s].total_rows(), live.slaves[s].total_rows());
    }
}

#[test]
fn prop_rebalance_is_minimal_disruption() {
    // For any (from, to) shard counts and id set: a planned rebalance
    // changes owners for exactly the ids in moved slots; every other
    // route is byte-stable across the epoch bump, and the new load is
    // balanced within one slot.
    check(
        "rebalance-minimal-disruption",
        &PairOf(PairOf(U64Range(1, 12), U64Range(1, 12)), VecOf(U64Range(0, 1 << 40), 80)),
        60,
        |((from, to), ids)| {
            let map = SlotMap::uniform(128, *from as u32);
            let moves = balance_moves(&map, *to as u32);
            let new = map.rebalanced(&moves).map_err(|e| e.to_string())?;
            if new.epoch != map.epoch + 1 {
                return Err("epoch did not bump".into());
            }
            let moved: std::collections::HashSet<u16> =
                moves.iter().map(|(s, _)| *s).collect();
            for &id in ids {
                if new.slot_of(id) != map.slot_of(id) {
                    return Err(format!("slot hash changed for id {id}"));
                }
                if !moved.contains(&map.slot_of(id)) && new.shard_of(id) != map.shard_of(id) {
                    return Err(format!("unmoved id {id} changed owner"));
                }
            }
            // Minimality: every planned move changes an owner.
            let diff = (0..128u16)
                .filter(|&s| new.shard_of_slot(s) != map.shard_of_slot(s))
                .count();
            if diff != moves.len() {
                return Err(format!("{} moves for {diff} ownership changes", moves.len()));
            }
            // Balance within one slot; nothing routed past the target.
            let mut load = vec![0usize; *to as usize];
            for slot in 0..128u16 {
                let owner = new.shard_of_slot(slot) as usize;
                if owner >= load.len() {
                    return Err(format!("slot {slot} routed past target shard count"));
                }
                load[owner] += 1;
            }
            for (shard, &l) in load.iter().enumerate() {
                if (l as i64 - (128 / *to) as i64).abs() > 1 {
                    return Err(format!("shard {shard} load {l} unbalanced: {load:?}"));
                }
            }
            // Encode/decode round trip preserves the routing bytes.
            if SlotMap::from_bytes(&new.to_bytes()).map_err(|e| e.to_string())? != new {
                return Err("encode/decode round trip diverged".into());
            }
            Ok(())
        },
    );
}

/// PR-6 follow-up regression: a slave chain rebuild must not resurrect
/// rows whose slots migrated away *after* the donor's base chunk was
/// sealed. `recover_slave` replays every master's chain in shard order;
/// with slots moved 1 → 0, the recipient's fresh delta lands first and
/// the donor's stale base second — without the owner filter the stale
/// copy wins and the moved rows silently roll back.
#[test]
fn chain_rebuild_respects_migrated_slot_ownership() {
    use weips::config::{CkptMode, ClusterConfig};
    use weips::coordinator::{ClusterOpts, LocalCluster};
    if !weips::runtime::default_artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let cluster = LocalCluster::new(ClusterOpts {
        cluster: ClusterConfig {
            model_kind: ModelKind::Lr,
            master_shards: 2,
            slave_shards: 1,
            slave_replicas: 1,
            queue_partitions: 2,
            ckpt_mode: CkptMode::Incremental,
            gather_mode: GatherMode::Realtime,
            ..Default::default()
        },
        ..Default::default()
    })
    .expect("cluster");
    // Seed and seal the pre-migration base chunks (v1).
    for _ in 0..12 {
        cluster.train_step().unwrap();
        cluster.sync_tick().unwrap();
    }
    cluster.flush_sync().unwrap();
    cluster.checkpoint().unwrap();
    // Keep training so the live rows drift past the sealed base values.
    for _ in 0..12 {
        cluster.train_step().unwrap();
        cluster.sync_tick().unwrap();
    }
    // Move a donor-1 slot batch to shard 0, then seal the post-migration
    // delta (v2): the moved rows' authoritative values now live in shard
    // 0's chain, while shard 1's base still carries the stale copies.
    let map = cluster.master_router.snapshot();
    let slots = weips::reshard::pick_donor_slots(&map, 1, 8).unwrap();
    cluster.migrate_slots(1, 0, &slots).unwrap();
    cluster.flush_sync().unwrap();
    cluster.checkpoint().unwrap();

    // Ground truth: what the streaming-synced replica serves for ids in
    // the moved slots right now.
    let map = cluster.master_router.snapshot();
    let moved: std::collections::HashSet<u16> = slots.iter().copied().collect();
    let mut ids: Vec<u64> = cluster
        .serving_requests(64)
        .into_iter()
        .flatten()
        .filter(|&id| moved.contains(&map.slot_of(id)))
        .collect();
    ids.sort_unstable();
    ids.dedup();
    assert!(!ids.is_empty(), "workload produced no ids in the moved slots");
    let pull = |ids: &[u64]| {
        cluster.slaves[0][0]
            .sparse_pull(&SparsePull {
                model: cluster.cfg.model_name.clone(),
                table: "w".into(),
                ids: ids.to_vec(),
                slot: "w".into(),
            })
            .unwrap()
            .values
    };
    let before = pull(&ids);
    assert!(
        before.iter().any(|&v| v != 0.0),
        "no trained rows in the moved slots — migration test is vacuous"
    );

    // Rebuild the replica from the checkpoint chains.
    cluster.recover_slave(0, 0).unwrap();
    let after = pull(&ids);
    assert_eq!(
        before, after,
        "chain rebuild resurrected pre-migration values for moved rows"
    );
}
