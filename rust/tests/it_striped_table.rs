//! Integration: lock-striped parameter tables on the full sync path.
//!
//! Covers the striped-table contract end to end, no AOT artifacts needed:
//! entry-filtered ids never reach any stripe (and never sync), expired
//! ids leave their owning stripe *and* arrive at slaves as deletes, the
//! checkpoint encoding is byte-stable across stripe counts at the shard
//! level, and concurrent push traffic across stripes survives a live
//! gather/scatter pipeline without losing or duplicating state.

use std::sync::Arc;
use std::time::Duration;

use weips::config::{GatherMode, ModelKind, ModelSpec};
use weips::proto::{SparsePull, SparsePush};
use weips::queue::Queue;
use weips::runtime::ModelConfig;
use weips::server::master::MasterShard;
use weips::server::slave::SlaveShard;
use weips::sync::{Gather, Pusher, Router, Scatter, ServingWeights};
use weips::util::clock::ManualClock;

fn spec() -> ModelSpec {
    let cfg = ModelConfig {
        batch_train: 8,
        batch_predict: 2,
        fields: 4,
        dim: 2,
        hidden: 8,
        ftrl_block_rows: 64,
        ftrl_alpha: 0.1,
        ftrl_beta: 1.0,
        ftrl_l1: 0.01,
        ftrl_l2: 1.0,
    };
    ModelSpec::derive("ctr", ModelKind::Fm, &cfg)
}

fn master(entry_threshold: u32, stripes: usize, clock: Arc<ManualClock>) -> Arc<MasterShard> {
    Arc::new(
        MasterShard::with_stripes(0, spec(), None, entry_threshold, stripes, clock).unwrap(),
    )
}

fn slave(model_spec: &ModelSpec) -> Arc<SlaveShard> {
    let tables: Vec<(String, usize)> =
        model_spec.sparse.iter().map(|t| (t.name.clone(), t.dim)).collect();
    let dense: Vec<(String, usize)> =
        model_spec.dense.iter().map(|d| (d.name.clone(), d.len)).collect();
    let transform = Arc::new(ServingWeights::new(
        model_spec
            .sparse
            .iter()
            .map(|t| (t.name.clone(), model_spec.optimizer_for(&t.name).unwrap(), t.dim))
            .collect(),
    ));
    Arc::new(SlaveShard::new(0, 0, "ctr", tables, dense, transform, Router::new(1)))
}

fn push(m: &MasterShard, ids: Vec<u64>) {
    let grads = vec![1.0f32; ids.len()];
    m.sparse_push(&SparsePush { model: "ctr".into(), table: "w".into(), ids, grads }).unwrap();
}

#[test]
fn entry_filtered_ids_never_materialize_or_sync() {
    let clock = Arc::new(ManualClock::new(0));
    let m = master(3, 8, clock.clone());
    let mut gather = Gather::new(m.clone(), GatherMode::Realtime, clock.clone());
    let queue = Queue::default();
    let topic = queue.create_topic("sync.ctr", 1).unwrap();
    let pusher = Pusher::new(topic.clone(), 0);
    let s = slave(&m.spec);
    let mut scatter = Scatter::new(topic, s.clone(), 1, 1, clock);

    // Two observations of 30 ids: below the threshold of 3.
    for _ in 0..2 {
        push(&m, (0..30).collect());
    }
    assert_eq!(m.total_rows(), 0, "probation ids materialized");
    pusher.push_all(&gather.flush_now()).unwrap();
    scatter.poll(Duration::ZERO).unwrap();
    assert_eq!(s.total_rows(), 0, "probation ids leaked into the sync stream");

    // Third observation crosses the threshold everywhere.
    push(&m, (0..30).collect());
    assert_eq!(m.total_rows(), 30);
    pusher.push_all(&gather.flush_now()).unwrap();
    scatter.poll(Duration::ZERO).unwrap();
    assert_eq!(s.total_rows(), 30);
}

#[test]
fn expired_ids_evict_and_emit_sync_deletes() {
    let clock = Arc::new(ManualClock::new(0));
    let m = master(1, 8, clock.clone());
    let mut gather = Gather::new(m.clone(), GatherMode::Realtime, clock.clone());
    let queue = Queue::default();
    let topic = queue.create_topic("sync.ctr", 1).unwrap();
    let pusher = Pusher::new(topic.clone(), 0);
    let s = slave(&m.spec);
    let mut scatter = Scatter::new(topic, s.clone(), 1, 1, clock.clone());

    push(&m, (0..40).collect());
    pusher.push_all(&gather.flush_now()).unwrap();
    scatter.poll(Duration::ZERO).unwrap();
    assert_eq!(s.total_rows(), 40);

    // Refresh half the ids, expire the rest.
    clock.advance(10_000);
    push(&m, (0..20).collect());
    let evicted = m.expire_features(5_000);
    assert_eq!(evicted, 20);
    assert_eq!(m.total_rows(), 20);
    // The eviction must reach the slave as deletes through the queue.
    pusher.push_all(&gather.flush_now()).unwrap();
    scatter.poll(Duration::ZERO).unwrap();
    assert_eq!(s.total_rows(), 20, "expire did not propagate as sync deletes");
    let gone = s
        .sparse_pull(&SparsePull {
            model: "ctr".into(),
            table: "w".into(),
            ids: (20..40).collect(),
            slot: "w".into(),
        })
        .unwrap();
    assert!(gone.values.iter().all(|v| *v == 0.0));
}

#[test]
fn shard_snapshots_are_stable_across_stripe_counts() {
    let mut snaps = Vec::new();
    for stripes in [1usize, 4, 16] {
        let clock = Arc::new(ManualClock::new(0));
        let m = master(1, stripes, clock);
        for id in 0..100u64 {
            m.sparse_push(&SparsePush {
                model: "ctr".into(),
                table: "w".into(),
                ids: vec![id],
                grads: vec![id as f32 * 0.1 + 0.5],
            })
            .unwrap();
            m.sparse_push(&SparsePush {
                model: "ctr".into(),
                table: "v".into(),
                ids: vec![id],
                grads: vec![0.25, -0.25],
            })
            .unwrap();
        }
        snaps.push(m.snapshot());
    }
    assert_eq!(snaps[0], snaps[1], "1-stripe vs 4-stripe snapshots differ");
    assert_eq!(snaps[0], snaps[2], "1-stripe vs 16-stripe snapshots differ");
    // And a shard with a different stripe count restores them exactly.
    let clock = Arc::new(ManualClock::new(0));
    let m = master(1, 2, clock);
    m.restore(&snaps[2], None).unwrap();
    assert_eq!(m.total_rows(), 200);
    assert_eq!(m.snapshot(), snaps[0], "restore did not round-trip byte-stably");
}

#[test]
fn concurrent_pushes_with_live_gather_lose_nothing() {
    let clock = Arc::new(ManualClock::new(0));
    let m = master(1, 8, clock.clone());
    let mut gather = Gather::new(m.clone(), GatherMode::Realtime, clock.clone());
    let queue = Queue::default();
    let topic = queue.create_topic("sync.ctr", 1).unwrap();
    let pusher = Pusher::new(topic.clone(), 0);
    let s = slave(&m.spec);
    let mut scatter = Scatter::new(topic, s.clone(), 1, 1, clock);

    // 4 pusher threads on disjoint id ranges, gather polling live.
    let per = 400u64;
    let done = Arc::new(std::sync::atomic::AtomicUsize::new(0));
    let mut handles = Vec::new();
    for w in 0..4u64 {
        let m = m.clone();
        let done = done.clone();
        handles.push(std::thread::spawn(move || {
            for _ in 0..10 {
                let ids: Vec<u64> = (w * per..(w + 1) * per).collect();
                let grads = vec![0.5f32; ids.len()];
                m.sparse_push(&SparsePush {
                    model: "ctr".into(),
                    table: "w".into(),
                    ids,
                    grads,
                })
                .unwrap();
            }
            done.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        }));
    }
    // Drive the pipeline while writers run (gather snapshots race applies
    // on other stripes — the non-blocking property under test).
    while done.load(std::sync::atomic::Ordering::SeqCst) < 4 {
        pusher.push_all(&gather.poll()).unwrap();
        scatter.poll(Duration::ZERO).unwrap();
    }
    for h in handles {
        h.join().unwrap();
    }
    // Final flush: slave converges to exactly the master's state.
    pusher.push_all(&gather.flush_now()).unwrap();
    scatter.poll(Duration::ZERO).unwrap();
    assert_eq!(m.total_rows(), 4 * per as usize);
    assert_eq!(s.total_rows(), 4 * per as usize);
    let ids: Vec<u64> = (0..4 * per).collect();
    let mw = m
        .sparse_pull(&SparsePull {
            model: "ctr".into(),
            table: "w".into(),
            ids: ids.clone(),
            slot: "w".into(),
        })
        .unwrap();
    let sw = s
        .sparse_pull(&SparsePull { model: "ctr".into(), table: "w".into(), ids, slot: "w".into() })
        .unwrap();
    assert_eq!(mw.values, sw.values, "slave diverged from master after quiesce");
    // FTRL with |z| > l1 after 10 unit-ish updates: weights are nonzero.
    assert!(mw.values.iter().all(|v| *v != 0.0));
}
