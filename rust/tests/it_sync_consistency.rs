//! Integration: streaming-sync consistency across the full stack.
//!
//! After training through the real trainer (PJRT graphs) and flushing the
//! collector→gather→pusher→queue→scatter pipeline, every slave replica
//! must serve exactly the master's transformed state (§4.1 eventual
//! consistency at quiesce).

use weips::config::{ClusterConfig, GatherMode, ModelKind};
use weips::coordinator::{ClusterOpts, LocalCluster};
use weips::proto::SparsePull;
use weips::sample::WorkloadConfig;

fn artifacts_ready() -> bool {
    weips::runtime::default_artifacts_dir().join("manifest.json").exists()
}

fn cluster(kind: ModelKind, gather: GatherMode) -> LocalCluster {
    LocalCluster::new(ClusterOpts {
        cluster: ClusterConfig {
            model_kind: kind,
            master_shards: 4,
            slave_shards: 2,
            slave_replicas: 2,
            queue_partitions: 4,
            gather_mode: gather,
            ..Default::default()
        },
        workload: WorkloadConfig { ids_per_field: 2_000, seed: 11, ..Default::default() },
        ..Default::default()
    })
    .expect("cluster")
}

/// Collect every materialized id of a master-side table (via snapshots —
/// tables are not otherwise enumerable through the public RPC surface).
fn master_ids(c: &LocalCluster, table: &str) -> Vec<u64> {
    let mut ids = Vec::new();
    for m in &c.masters {
        let snap = m.snapshot();
        ids.extend(snapshot_ids(&snap, table));
    }
    ids.sort();
    ids.dedup();
    ids
}

/// Parse a master snapshot and list ids of `table` (test helper).
fn snapshot_ids(snap: &[u8], want_table: &str) -> Vec<u64> {
    use weips::codec::Reader;
    let mut r = Reader::new(snap);
    let _shard = r.get_u32().unwrap();
    let n_sparse = r.get_varint().unwrap() as usize;
    let mut out = Vec::new();
    for _ in 0..n_sparse {
        let name = r.get_str().unwrap();
        let _dim = r.get_u32().unwrap();
        let _width = r.get_u32().unwrap();
        let count = r.get_varint().unwrap() as usize;
        for _ in 0..count {
            let id = r.get_varint().unwrap();
            let _ts = r.get_varint().unwrap();
            let _updates = r.get_u32().unwrap();
            let vals = r.get_f32_slice().unwrap();
            let _ = vals;
            if name == want_table {
                out.push(id);
            }
        }
    }
    out
}

#[test]
fn slaves_converge_to_master_state_all_gather_modes() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    for gather in [
        GatherMode::Realtime,
        GatherMode::Threshold(500),
        GatherMode::Period(50),
    ] {
        let c = cluster(ModelKind::Fm, gather);
        for _ in 0..12 {
            c.train_step().unwrap();
            c.sync_tick().unwrap();
        }
        c.flush_sync().unwrap();
        assert_eq!(c.sync_lag(), 0);

        let ids = master_ids(&c, "w");
        assert!(!ids.is_empty());
        // Master serving weights.
        let (_, master_w) = sharded_master_pull(&c, "w", &ids);
        // Every replica of the owning slave shard serves the same values.
        let router = weips::sync::Router::new(c.cfg.slave_shards);
        for (i, &id) in ids.iter().enumerate() {
            let shard = router.shard_of(id) as usize;
            for replica in &c.slaves[shard] {
                let v = replica
                    .sparse_pull(&SparsePull {
                        model: "ctr".into(),
                        table: "w".into(),
                        ids: vec![id],
                        slot: "w".into(),
                    })
                    .unwrap();
                assert!(
                    (v.values[0] - master_w[i]).abs() < 1e-6,
                    "gather {gather:?}: id {id} master {} slave {}",
                    master_w[i],
                    v.values[0]
                );
            }
        }
    }
}

fn sharded_master_pull(c: &LocalCluster, table: &str, ids: &[u64]) -> (u32, Vec<f32>) {
    let router = weips::sync::Router::new(c.cfg.master_shards);
    let mut out = vec![0.0f32; ids.len()];
    for (i, &id) in ids.iter().enumerate() {
        let m = &c.masters[router.shard_of(id) as usize];
        let v = m
            .sparse_pull(&SparsePull {
                model: "ctr".into(),
                table: table.into(),
                ids: vec![id],
                slot: "w".into(),
            })
            .unwrap();
        out[i] = v.values[0];
    }
    (1, out)
}

#[test]
fn dense_tables_sync_to_slaves() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let c = cluster(ModelKind::DeepFm, GatherMode::Realtime);
    for _ in 0..5 {
        c.train_step().unwrap();
        c.sync_tick().unwrap();
    }
    c.flush_sync().unwrap();
    // Master dense state (shard 0 owns dense).
    let master_bias = c
        .masters[0]
        .dense_pull(&weips::proto::DensePull { model: "ctr".into(), table: "bias".into() })
        .unwrap()
        .values;
    let master_w1 = c
        .masters[0]
        .dense_pull(&weips::proto::DensePull { model: "ctr".into(), table: "w1".into() })
        .unwrap()
        .values;
    for shard in &c.slaves {
        for replica in shard {
            let b = replica
                .dense_pull(&weips::proto::DensePull { model: "ctr".into(), table: "bias".into() })
                .unwrap();
            assert_eq!(b.values, master_bias);
            let w1 = replica
                .dense_pull(&weips::proto::DensePull { model: "ctr".into(), table: "w1".into() })
                .unwrap();
            assert_eq!(w1.values, master_w1);
        }
    }
    assert!(master_w1.iter().any(|v| *v != 0.0), "tower trained");
}

#[test]
fn feature_expire_propagates_deletes_to_slaves() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut cfg = ClusterConfig {
        model_kind: ModelKind::Lr,
        master_shards: 2,
        slave_shards: 1,
        slave_replicas: 1,
        queue_partitions: 2,
        gather_mode: GatherMode::Realtime,
        ..Default::default()
    };
    cfg.feature_ttl_ms = 1; // everything older than 1ms expires
    let c = LocalCluster::new(ClusterOpts {
        cluster: cfg,
        workload: WorkloadConfig { ids_per_field: 500, seed: 3, ..Default::default() },
        ..Default::default()
    })
    .unwrap();
    for _ in 0..5 {
        c.train_step().unwrap();
        c.sync_tick().unwrap();
    }
    c.flush_sync().unwrap();
    let before: usize = c.slaves[0][0].total_rows();
    assert!(before > 0);
    std::thread::sleep(std::time::Duration::from_millis(10));
    for m in &c.masters {
        assert!(m.expire_features(1) > 0);
    }
    c.flush_sync().unwrap();
    let after = c.slaves[0][0].total_rows();
    assert_eq!(after, 0, "expired rows must be deleted on slaves ({before} -> {after})");
}

#[test]
fn predictions_match_between_fresh_sync_and_master_state() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let c = cluster(ModelKind::Fm, GatherMode::Threshold(100));
    for _ in 0..10 {
        c.train_step().unwrap();
        c.sync_tick().unwrap();
    }
    c.flush_sync().unwrap();
    let reqs = c.serving_requests(16);
    let preds = c.predict(&reqs).unwrap();
    assert_eq!(preds.len(), 16);
    assert!(preds.iter().all(|p| p.is_finite() && (0.0..=1.0).contains(p)));
    // Serving predictions should differ from the untrained prior (0.5)
    // for at least some requests — proof that synced state is used.
    assert!(preds.iter().any(|p| (p - 0.5).abs() > 1e-3));
}
