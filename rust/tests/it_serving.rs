//! Integration: the serving read path — hot-id cache coherence,
//! replica-aware pull fan-out, and QoS admission control.
//!
//! Manual assembly, no AOT artifacts. Covers the four serving-path
//! invariants end to end:
//! - cached pulls are byte-identical to uncached pulls over the same
//!   slave state, before and after streamed updates;
//! - one-tick freshness through the *real* scatter: an update pushed to
//!   a master and drained through gather -> queue -> scatter is visible
//!   to the next cached pull, because the cache is invalidated inside
//!   `Scatter::poll` before it returns;
//! - the replica fan-out spreads serving load across a group's healthy
//!   replicas (round-robin lease accounting);
//! - QoS admission sheds over-cap bulk traffic with a typed NACK while
//!   concurrent predict pulls keep flowing, uncorrupted.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use weips::config::{GatherMode, ModelKind, ModelSpec};
use weips::net::{Channel, RpcOptions, RpcServer, Service};
use weips::optim::{Ftrl, FtrlHyper, Optimizer};
use weips::proto::{SparsePull, SyncBatch, SyncEntry, SyncOp};
use weips::queue::Queue;
use weips::replica::{BalancePolicy, ReplicaGroup};
use weips::runtime::ModelConfig;
use weips::server::master::{MasterService, MasterShard};
use weips::server::slave::{SlaveService, SlaveShard};
use weips::server::{default_qos_policy, methods};
use weips::sync::{Gather, Pusher, Router, Scatter, ScatterTap, ServingWeights};
use weips::util::clock::ManualClock;
use weips::worker::{HotIdCache, ShardedClient, SlaveClient, SlaveEndpoint};
use weips::Result;

fn spec() -> ModelSpec {
    let cfg = ModelConfig {
        batch_train: 8,
        batch_predict: 2,
        fields: 4,
        dim: 2,
        hidden: 8,
        ftrl_block_rows: 64,
        ftrl_alpha: 0.05,
        ftrl_beta: 1.0,
        ftrl_l1: 1.0,
        ftrl_l2: 1.0,
    };
    ModelSpec::derive("ctr", ModelKind::Fm, &cfg)
}

fn transform() -> Arc<ServingWeights> {
    let ftrl: Arc<dyn Optimizer> = Arc::new(Ftrl::new(FtrlHyper::default()));
    Arc::new(ServingWeights::new(vec![("w".into(), ftrl.clone(), 1), ("v".into(), ftrl, 2)]))
}

fn slave_shard(s: u32, r: u32, shards: u32) -> Arc<SlaveShard> {
    Arc::new(SlaveShard::new(
        s,
        r,
        "ctr",
        vec![("w".into(), 1), ("v".into(), 2)],
        vec![("bias".into(), 1)],
        transform(),
        Router::new(shards),
    ))
}

/// Build `shards x replicas` slaves behind local channels.
fn slave_fleet(
    shards: u32,
    replicas: u32,
) -> (Vec<Arc<ReplicaGroup<SlaveEndpoint>>>, Vec<Vec<Arc<SlaveShard>>>) {
    let mut groups = Vec::new();
    let mut all = Vec::new();
    for s in 0..shards {
        let mut eps = Vec::new();
        let mut reps = Vec::new();
        for r in 0..replicas {
            let shard = slave_shard(s, r, shards);
            let ch = Channel::local(Arc::new(SlaveService { shard: shard.clone() }));
            eps.push(Arc::new(SlaveEndpoint::local(ch, shard.clone())));
            reps.push(shard);
        }
        groups.push(Arc::new(ReplicaGroup::new(eps, BalancePolicy::RoundRobin)));
        all.push(reps);
    }
    (groups, all)
}

/// Apply one serving upsert to every replica of the owning shard.
fn apply_row(slaves: &[Vec<Arc<SlaveShard>>], id: u64, value: f32) -> SyncBatch {
    let router = Router::new(slaves.len() as u32);
    let batch = SyncBatch {
        model: "ctr".into(),
        table: "w".into(),
        shard: 0,
        seq: 0,
        created_ms: 0,
        entries: vec![SyncEntry { id, op: SyncOp::Upsert(vec![2.0, 1.0, value]) }],
        dense: vec![],
    };
    for replica in &slaves[router.shard_of(id) as usize] {
        replica.apply_batch(&batch).unwrap();
    }
    batch
}

#[test]
fn cached_pulls_byte_identical_to_uncached() {
    let (groups, slaves) = slave_fleet(2, 2);
    for id in 0..400u64 {
        apply_row(&slaves, id, id as f32);
    }
    let uncached = SlaveClient::new("ctr", groups.clone());
    let mut cached = SlaveClient::new("ctr", groups);
    let cache = HotIdCache::new(1 << 16);
    cached.set_cache(cache.clone());

    // Several overlapping batches: fills, then hits, always identical.
    for round in 0..5u64 {
        let ids: Vec<u64> = (0..64).map(|j| (round * 37 + j * 3) % 400).collect();
        assert_eq!(
            uncached.sparse_pull("w", &ids).unwrap(),
            cached.sparse_pull("w", &ids).unwrap(),
            "round {round}"
        );
    }
    assert!(cache.stats.hits.load(Ordering::Relaxed) > 0, "cache never hit");

    // Streamed updates invalidate; identity must hold afterwards too.
    for id in (0..400u64).step_by(5) {
        let batch = apply_row(&slaves, id, id as f32 + 1000.0);
        cache.on_applied(std::slice::from_ref(&batch));
    }
    let ids: Vec<u64> = (0..400).collect();
    assert_eq!(
        uncached.sparse_pull("w", &ids).unwrap(),
        cached.sparse_pull("w", &ids).unwrap(),
        "identity broken after invalidation round"
    );
}

/// The real pipeline: master -> gather -> queue -> scatter(-> tap) ->
/// slave, with the cache registered exactly as the coordinator wires it.
#[test]
fn one_tick_freshness_through_real_scatter() {
    const MASTERS: u32 = 2;
    let clock = Arc::new(ManualClock::new(0));
    let queue = Queue::new(1 << 24);
    let topic = queue.create_topic("sync.ctr", MASTERS as usize).unwrap();
    let master_router = Router::new(MASTERS);

    let mut masters = Vec::new();
    let mut gathers = Vec::new();
    let mut pushers = Vec::new();
    for i in 0..MASTERS {
        let m = Arc::new(MasterShard::new(i, spec(), None, 1, clock.clone()).unwrap());
        gathers.push(Mutex::new(Gather::new(m.clone(), GatherMode::Realtime, clock.clone())));
        pushers.push(Pusher::new(topic.clone(), i));
        masters.push(m);
    }
    let shard = slave_shard(0, 0, 1);
    let cache = HotIdCache::new(1 << 16);
    let mut scatter = Scatter::new(topic.clone(), shard.clone(), MASTERS, 1, clock.clone());
    scatter.add_tap(cache.clone());

    let channels: Vec<Channel> = masters
        .iter()
        .map(|m| Channel::local(Arc::new(MasterService { shard: m.clone(), store: None })))
        .collect();
    let trainer = ShardedClient::with_router("ctr", channels, master_router);
    let ch = Channel::local(Arc::new(SlaveService { shard: shard.clone() }));
    let group = Arc::new(ReplicaGroup::new(
        vec![Arc::new(SlaveEndpoint::local(ch, shard.clone()))],
        BalancePolicy::RoundRobin,
    ));
    let mut serving = SlaveClient::new("ctr", vec![group]);
    serving.set_cache(cache.clone());

    let drain = |scatter: &mut Scatter| loop {
        scatter.poll(Duration::ZERO).unwrap();
        if scatter.lag() == 0 {
            break;
        }
    };
    let sync_tick = |scatter: &mut Scatter| {
        for (g, p) in gathers.iter().zip(&pushers) {
            let batches = g.lock().unwrap().flush_now();
            p.push_all(&batches).unwrap();
        }
        drain(scatter);
    };

    let ids: Vec<u64> = (0..32).collect();
    let grads = vec![2.0f32; ids.len()];
    trainer.sparse_push("w", &ids, &grads).unwrap();
    sync_tick(&mut scatter);

    let (_, first) = serving.sparse_pull("w", &ids).unwrap(); // fill
    let (_, second) = serving.sparse_pull("w", &ids).unwrap(); // hits
    assert_eq!(first, second);
    assert!(cache.stats.hits.load(Ordering::Relaxed) >= ids.len() as u64);

    // Another gradient lands on the masters; until the scatter drains,
    // cache and slave agree on the old value (both lag together)...
    let grads = vec![1.0f32; ids.len()];
    trainer.sparse_push("w", &ids, &grads).unwrap();
    let (_, before_tick) = serving.sparse_pull("w", &ids).unwrap();
    assert_eq!(before_tick, second, "cache must not outrun the slave");

    // ...and one sync tick later the cached read serves the new value,
    // byte-identical to reading the slave table directly.
    sync_tick(&mut scatter);
    let (_, after) = serving.sparse_pull("w", &ids).unwrap();
    assert_ne!(after, second, "update never became visible");
    let direct = shard
        .sparse_pull(&SparsePull {
            model: "ctr".into(),
            table: "w".into(),
            ids: ids.clone(),
            slot: "w".into(),
        })
        .unwrap();
    assert_eq!(after, direct.values, "cached read != slave truth after tick");
    assert!(cache.stats.invalidations.load(Ordering::Relaxed) >= ids.len() as u64);
}

#[test]
fn replica_fanout_splits_load() {
    let (groups, slaves) = slave_fleet(1, 3);
    for id in 0..32u64 {
        apply_row(&slaves, id, id as f32);
    }
    let client = SlaveClient::new("ctr", groups);
    for i in 0..30u64 {
        let ids: Vec<u64> = (0..8).map(|j| (i + j) % 32).collect();
        client.sparse_pull("w", &ids).unwrap();
    }
    let served = client.group(0).served_counts();
    assert_eq!(served.iter().sum::<u64>(), 30);
    assert!(
        served.iter().all(|&c| c >= 9),
        "round-robin fan-out skewed: {served:?}"
    );
    assert_eq!(client.group(0).mean_latency_ns().len(), 3);

    // A dead replica's share fails over to the survivors.
    slaves[0][0].set_healthy(false);
    for i in 0..12u64 {
        let ids: Vec<u64> = (0..8).map(|j| (i + j) % 32).collect();
        client.sparse_pull("w", &ids).unwrap();
    }
    let after = client.group(0).served_counts();
    assert_eq!(after[0], served[0], "dead replica kept serving");
    assert_eq!(after.iter().sum::<u64>(), 42);
}

/// Delegates predict traffic to a real slave; bulk methods park the
/// handler long enough to hold their admission slot.
struct SlowBulkSlave {
    inner: SlaveService,
    bulk_calls: AtomicU64,
}

impl Service for SlowBulkSlave {
    fn call(&self, method: u16, payload: &[u8]) -> Result<Vec<u8>> {
        if method == methods::MIGRATE_PULL {
            self.bulk_calls.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(Duration::from_millis(250));
            return Ok(Vec::new());
        }
        self.inner.call(method, payload)
    }
}

#[test]
fn qos_sheds_bulk_with_typed_nack_while_pulls_flow() {
    let shard = slave_shard(0, 0, 1);
    for id in 0..64u64 {
        let batch = SyncBatch {
            model: "ctr".into(),
            table: "w".into(),
            shard: 0,
            seq: 0,
            created_ms: 0,
            entries: vec![SyncEntry { id, op: SyncOp::Upsert(vec![2.0, 1.0, id as f32]) }],
            dense: vec![],
        };
        shard.apply_batch(&batch).unwrap();
    }
    let svc = Arc::new(SlowBulkSlave {
        inner: SlaveService { shard: shard.clone() },
        bulk_calls: AtomicU64::new(0),
    });
    let server = RpcServer::serve_with(
        "127.0.0.1:0",
        svc.clone(),
        RpcOptions { threads: 4, qos: Some(default_qos_policy(1)), ..RpcOptions::default() },
    )
    .unwrap();
    let addr = server.addr().to_string();

    // A bulk migration hammers the server from two threads; with a
    // bulk cap of 1, at least one call must shed with the typed NACK.
    let flood: Vec<_> = (0..2)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let ch = Channel::remote(&addr, Duration::from_secs(5));
                let mut ok = 0u32;
                let mut shed = 0u32;
                for _ in 0..3 {
                    match ch.call(methods::MIGRATE_PULL, &[]) {
                        Ok(_) => ok += 1,
                        Err(e) if e.is_overloaded() => shed += 1,
                        Err(e) => panic!("bulk flood saw a non-typed error: {e}"),
                    }
                }
                (ok, shed)
            })
        })
        .collect();

    // Meanwhile predict pulls keep flowing through the same server and
    // stay byte-correct throughout the flood.
    let ch = Channel::remote(&addr, Duration::from_secs(5));
    let group = Arc::new(ReplicaGroup::new(
        vec![Arc::new(SlaveEndpoint::remote(ch))],
        BalancePolicy::RoundRobin,
    ));
    let client = SlaveClient::new("ctr", vec![group]);
    let ids: Vec<u64> = (0..64).collect();
    let expect: Vec<f32> = {
        let direct = shard
            .sparse_pull(&SparsePull {
                model: "ctr".into(),
                table: "w".into(),
                ids: ids.clone(),
                slot: "w".into(),
            })
            .unwrap();
        direct.values
    };
    for _ in 0..40 {
        let (_, vals) = client.sparse_pull("w", &ids).unwrap();
        assert_eq!(vals, expect, "in-flight pull corrupted during bulk flood");
    }

    let (mut ok, mut shed) = (0u32, 0u32);
    for h in flood {
        let (o, s) = h.join().unwrap();
        ok += o;
        shed += s;
    }
    assert!(ok >= 1, "no bulk call ever ran");
    assert!(shed >= 1, "bulk over cap was never shed");
    assert_eq!(svc.bulk_calls.load(Ordering::Relaxed) as u32, ok, "shed call reached the service");
    let stats = server.qos_stats().expect("qos enabled");
    use weips::net::QosClass;
    assert_eq!(stats[QosClass::Predict as usize].1, 0, "predict was shed: {stats:?}");
    assert!(stats[QosClass::Bulk as usize].1 >= 1);
}
