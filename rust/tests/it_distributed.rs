//! Integration: the distributed deployment shape — every hop over real
//! TCP RPC (broker, master shards, slave replicas, trainer, predictor),
//! exactly what the `weips` CLI roles launch as separate processes.

use std::sync::Arc;
use std::time::Duration;

use weips::config::{GatherMode, ModelKind, ModelSpec};
use weips::monitor::Monitor;
use weips::net::{Channel, RpcServer};
use weips::queue::{Queue, QueueService, RemoteLog, SyncLog};
use weips::replica::{BalancePolicy, ReplicaGroup};
use weips::runtime::Engine;
use weips::sample::{Workload, WorkloadConfig};
use weips::server::master::{MasterService, MasterShard};
use weips::server::slave::{SlaveService, SlaveShard};
use weips::sync::{Gather, Pusher, Router, Scatter, ServingWeights};
use weips::util::clock::SystemClock;
use weips::worker::{Predictor, ShardedClient, SlaveClient, SlaveEndpoint, Trainer};

const TIMEOUT: Duration = Duration::from_secs(10);
const MASTERS: u32 = 2;
const SLAVES: u32 = 2;

fn artifacts_ready() -> bool {
    weips::runtime::default_artifacts_dir().join("manifest.json").exists()
}

#[test]
fn full_stack_over_tcp() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let engine = Arc::new(Engine::load(weips::runtime::default_artifacts_dir()).unwrap());
    let spec = ModelSpec::derive("ctr", ModelKind::Fm, engine.config());
    let clock = Arc::new(SystemClock);

    // --- broker process ---
    let queue = Queue::default();
    let topic = queue.create_topic("sync.ctr", MASTERS as usize).unwrap();
    let broker_srv = RpcServer::serve("127.0.0.1:0", Arc::new(QueueService { topic })).unwrap();
    let broker_addr = broker_srv.addr().to_string();

    // --- master processes (shard server + sync pump) ---
    let mut master_addrs = Vec::new();
    let mut master_servers = Vec::new();
    let mut pumps = Vec::new();
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    for shard in 0..MASTERS {
        let master = Arc::new(
            MasterShard::new(shard, spec.clone(), Some(engine.clone()), 1, clock.clone()).unwrap(),
        );
        let srv = RpcServer::serve(
            "127.0.0.1:0",
            Arc::new(MasterService { shard: master.clone(), store: None }),
        )
        .unwrap();
        master_addrs.push(srv.addr().to_string());
        master_servers.push(srv);
        // Sync pump thread: gather -> remote broker.
        let log: Arc<dyn SyncLog> = Arc::new(
            RemoteLog::connect(Channel::remote(&broker_addr, TIMEOUT)).unwrap(),
        );
        let mut gather = Gather::new(master.clone(), GatherMode::Realtime, clock.clone());
        let pusher = Pusher::new(log, shard);
        let stop2 = stop.clone();
        pumps.push(std::thread::spawn(move || {
            while !stop2.load(std::sync::atomic::Ordering::Acquire) {
                let batches = gather.poll();
                if batches.is_empty() {
                    std::thread::sleep(Duration::from_millis(2));
                } else {
                    pusher.push_all(&batches).unwrap();
                }
            }
        }));
    }

    // --- slave processes (replica server + scatter pump) ---
    let transform = Arc::new(ServingWeights::new(
        spec.sparse
            .iter()
            .map(|t| (t.name.clone(), spec.optimizer_for(&t.name).unwrap(), t.dim))
            .collect(),
    ));
    let tables: Vec<(String, usize)> =
        spec.sparse.iter().map(|t| (t.name.clone(), t.dim)).collect();
    let dense: Vec<(String, usize)> =
        spec.dense.iter().map(|d| (d.name.clone(), d.len)).collect();
    let mut groups = Vec::new();
    let mut slave_servers = Vec::new();
    for shard in 0..SLAVES {
        let slave = Arc::new(SlaveShard::new(
            shard,
            0,
            "ctr",
            tables.clone(),
            dense.clone(),
            transform.clone(),
            Router::new(SLAVES),
        ));
        let srv =
            RpcServer::serve("127.0.0.1:0", Arc::new(SlaveService { shard: slave.clone() }))
                .unwrap();
        let addr = srv.addr().to_string();
        slave_servers.push(srv);
        let log: Arc<dyn SyncLog> = Arc::new(
            RemoteLog::connect(Channel::remote(&broker_addr, TIMEOUT)).unwrap(),
        );
        let mut scatter = Scatter::new(log, slave, MASTERS, SLAVES, clock.clone());
        let stop2 = stop.clone();
        pumps.push(std::thread::spawn(move || {
            while !stop2.load(std::sync::atomic::Ordering::Acquire) {
                if scatter.poll(Duration::from_millis(10)).unwrap() == 0 {
                    std::thread::sleep(Duration::from_millis(2));
                }
            }
        }));
        groups.push(Arc::new(ReplicaGroup::new(
            vec![Arc::new(SlaveEndpoint::remote(Channel::remote(&addr, TIMEOUT)))],
            BalancePolicy::RoundRobin,
        )));
    }

    // --- trainer process ---
    let channels: Vec<Channel> =
        master_addrs.iter().map(|a| Channel::remote(a, TIMEOUT)).collect();
    let monitor = Arc::new(Monitor::new(2048));
    let trainer = Trainer::new(
        engine.clone(),
        spec.clone(),
        ShardedClient::new("ctr", channels),
        monitor.clone(),
    );
    let mut workload = Workload::new(WorkloadConfig {
        fields: spec.fields,
        ids_per_field: 500,
        seed: 31,
        ..Default::default()
    });
    let mut losses = Vec::new();
    for step in 0..20u64 {
        let samples = workload.batch(step * 100, spec.batch_train);
        losses.push(trainer.train_batch(&samples).unwrap().loss);
    }
    assert!(losses.iter().all(|l| l.is_finite()));
    assert!(losses.last().unwrap() < losses.first().unwrap(), "{losses:?}");

    // --- predictor process (waits for sync to catch up) ---
    let predictor = Predictor::new(engine, spec.clone(), SlaveClient::new("ctr", groups));
    let reqs: Vec<Vec<u64>> = workload
        .batch(10_000, 8)
        .into_iter()
        .map(|s| s.ids)
        .collect();
    // Give the pumps a moment to flush everything through TCP.
    let deadline = std::time::Instant::now() + Duration::from_secs(15);
    let preds = loop {
        let preds = predictor.predict(&reqs).unwrap();
        if preds.iter().any(|p| (p - 0.5).abs() > 1e-3) || std::time::Instant::now() > deadline {
            break preds;
        }
        std::thread::sleep(Duration::from_millis(50));
    };
    assert_eq!(preds.len(), 8);
    assert!(
        preds.iter().any(|p| (p - 0.5).abs() > 1e-3),
        "slaves never received synced weights over TCP: {preds:?}"
    );

    stop.store(true, std::sync::atomic::Ordering::Release);
    for p in pumps {
        let _ = p.join();
    }
}
