//! Integration: the parallel streaming-sync pipeline under concurrent
//! trainer traffic.
//!
//! N threads hammer `sparse_push` on one master shard while a sync thread
//! drives gather (pooled per-stripe snapshots) → pusher → queue → scatter
//! (pooled per-stripe applies). At quiesce the slave must serve exactly
//! the master's transformed state — no lost or duplicated upserts — and
//! the pipeline's accounting (`GatherStats`, `ScatterStats`, pusher
//! counters) must agree end to end. Runs without AOT artifacts.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use weips::config::{GatherMode, ModelKind, ModelSpec};
use weips::optim::{Ftrl, FtrlHyper, Optimizer};
use weips::proto::{SparsePull, SparsePush};
use weips::queue::Queue;
use weips::runtime::ModelConfig;
use weips::server::master::MasterShard;
use weips::server::slave::SlaveShard;
use weips::sync::{Gather, Pusher, Router, Scatter, ServingWeights};
use weips::util::clock::ManualClock;
use weips::util::ThreadPool;

const ID_SPACE: u64 = 2_000;

fn spec() -> ModelSpec {
    let cfg = ModelConfig {
        batch_train: 8,
        batch_predict: 2,
        fields: 4,
        dim: 2,
        hidden: 8,
        ftrl_block_rows: 64,
        ftrl_alpha: 0.05,
        ftrl_beta: 1.0,
        ftrl_l1: 1.0,
        ftrl_l2: 1.0,
    };
    ModelSpec::derive("ctr", ModelKind::Fm, &cfg)
}

fn slave(stripes: usize) -> Arc<SlaveShard> {
    let ftrl: Arc<dyn Optimizer> = Arc::new(Ftrl::new(FtrlHyper::default()));
    Arc::new(SlaveShard::with_stripes(
        0,
        0,
        "ctr",
        vec![("w".into(), 1), ("v".into(), 2)],
        vec![("bias".into(), 1)],
        Arc::new(ServingWeights::new(vec![
            ("w".into(), ftrl.clone(), 1),
            ("v".into(), ftrl, 2),
        ])),
        Router::new(1),
        stripes,
    ))
}

#[test]
fn concurrent_push_with_streaming_sync_converges() {
    let clock = Arc::new(ManualClock::new(0));
    let master =
        Arc::new(MasterShard::with_stripes(0, spec(), None, 1, 8, clock.clone()).unwrap());
    let pool = Arc::new(ThreadPool::new(4, "it-sync"));
    let queue = Queue::new(1 << 26);
    let topic = queue.create_topic("sync.ctr", 1).unwrap();
    let serving = slave(8);
    let stop = Arc::new(AtomicBool::new(false));

    // The sync pipeline runs concurrently with the pushers: gather with
    // pooled snapshots, scatter with pooled applies, sharing one pool.
    let sync_thread = {
        let master = master.clone();
        let clock = clock.clone();
        let topic = topic.clone();
        let serving = serving.clone();
        let stop = stop.clone();
        let pool = pool.clone();
        std::thread::spawn(move || {
            let mut gather = Gather::with_pool(
                master,
                GatherMode::Threshold(256),
                clock.clone(),
                Some(pool.clone()),
            );
            let pusher = Pusher::new(topic.clone(), 0);
            let mut scatter =
                Scatter::with_pool(topic, serving, 1, 1, clock, Some(pool));
            while !stop.load(Ordering::Acquire) {
                let batches = gather.poll();
                pusher.push_all(&batches).unwrap();
                scatter.poll(Duration::ZERO).unwrap();
            }
            // Quiesced: force the tail through and drain the queue dry.
            let batches = gather.flush_now();
            pusher.push_all(&batches).unwrap();
            while scatter.lag() > 0 {
                scatter.poll(Duration::ZERO).unwrap();
            }
            (gather, scatter, pusher)
        })
    };

    // 4 pusher threads over overlapping id ranges: same-stripe contention
    // on the collector queues plus heavy windowed dedup.
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let master = master.clone();
        handles.push(std::thread::spawn(move || {
            for round in 0..50u64 {
                let ids: Vec<u64> =
                    (0..500).map(|i| (t * 500 + i + round * 7) % ID_SPACE).collect();
                let grads = vec![1.5f32; ids.len()];
                master
                    .sparse_push(&SparsePush {
                        model: "ctr".into(),
                        table: "w".into(),
                        ids,
                        grads,
                    })
                    .unwrap();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    stop.store(true, Ordering::Release);
    let (gather, scatter, pusher) = sync_thread.join().unwrap();

    // Convergence: the slave serves exactly the master's transformed rows.
    assert_eq!(serving.total_rows(), master.total_rows(), "row counts diverged");
    let ids: Vec<u64> = (0..ID_SPACE).collect();
    let master_w = master
        .sparse_pull(&SparsePull {
            model: "ctr".into(),
            table: "w".into(),
            ids: ids.clone(),
            slot: "w".into(),
        })
        .unwrap();
    let slave_w = serving
        .sparse_pull(&SparsePull {
            model: "ctr".into(),
            table: "w".into(),
            ids,
            slot: "w".into(),
        })
        .unwrap();
    assert_eq!(master_w.values.len(), slave_w.values.len());
    for (i, (m, s)) in master_w.values.iter().zip(&slave_w.values).enumerate() {
        assert!((m - s).abs() < 1e-6, "id {i}: master {m} != slave {s}");
    }
    // Heavy FTRL traffic must produce nonzero serving weights (the
    // assertion above is not comparing all-zeros).
    assert!(master_w.values.iter().any(|v| *v != 0.0));

    // Accounting consistency across the pipeline.
    let raw = gather.stats.raw_events.load(Ordering::Relaxed);
    let emitted = gather.stats.emitted_entries.load(Ordering::Relaxed);
    assert_eq!(
        raw,
        master.collector().total_recorded(),
        "gather drained a different event count than the collector recorded"
    );
    assert_eq!(master.collector().pending(), 0);
    assert!(emitted > 0 && emitted <= raw, "emitted {emitted} raw {raw}");
    assert!(gather.stats.repetition_rate() > 0.0, "overlapping pushes must dedup");
    // Every pushed batch was applied exactly once (single partition, one
    // consumer): no lost or duplicated batches.
    assert_eq!(
        scatter.stats.batches_applied.load(Ordering::Relaxed),
        pusher.stats.batches.load(Ordering::Relaxed)
    );
    assert_eq!(scatter.stats.decode_errors.load(Ordering::Relaxed), 0);
    assert_eq!(scatter.lag(), 0);
}

#[test]
fn pooled_and_sequential_pipelines_serve_identical_state() {
    // Same workload through a sequential pipeline and a pooled one (and a
    // different stripe count) must land byte-identical serving state.
    let run = |stripes: usize, threads: usize| -> (Vec<f32>, Vec<u8>) {
        let clock = Arc::new(ManualClock::new(0));
        let master = Arc::new(
            MasterShard::with_stripes(0, spec(), None, 1, stripes, clock.clone()).unwrap(),
        );
        let pool =
            (threads > 0).then(|| Arc::new(ThreadPool::new(threads, "it-sync-det")));
        let queue = Queue::new(1 << 26);
        let topic = queue.create_topic("sync.ctr", 1).unwrap();
        let serving = slave(stripes);
        let mut gather = Gather::with_pool(
            master.clone(),
            GatherMode::Threshold(1_000_000),
            clock.clone(),
            pool.clone(),
        );
        let pusher = Pusher::new(topic.clone(), 0);
        let mut scatter = Scatter::with_pool(topic, serving.clone(), 1, 1, clock, pool);
        for round in 0..20u64 {
            let ids: Vec<u64> = (0..300).map(|i| (i * 11 + round) % 700).collect();
            let grads = vec![2.0f32; ids.len()];
            master
                .sparse_push(&SparsePush { model: "ctr".into(), table: "w".into(), ids, grads })
                .unwrap();
        }
        pusher.push_all(&gather.flush_now()).unwrap();
        scatter.poll(Duration::ZERO).unwrap();
        let served = serving
            .sparse_pull(&SparsePull {
                model: "ctr".into(),
                table: "w".into(),
                ids: (0..700).collect(),
                slot: "w".into(),
            })
            .unwrap();
        (served.values, master.snapshot())
    };
    let (base_vals, base_snap) = run(1, 0);
    for (stripes, threads) in [(8, 0), (8, 4), (32, 2)] {
        let (vals, snap) = run(stripes, threads);
        assert_eq!(vals, base_vals, "served values diverged at {stripes}x{threads}");
        assert_eq!(snap, base_snap, "checkpoint bytes diverged at {stripes}x{threads}");
    }
}
