//! Integration: monitor + domino downgrade (§4.3).
//!
//! Train to a healthy model, checkpoint, corrupt the parameters (the
//! "abnormal change"), watch the progressive-validation window AUC
//! collapse, let the smoothed trigger fire, and verify the rollback
//! restores both master and serving state to the stable version.

use weips::config::{ClusterConfig, GatherMode, ModelKind};
use weips::coordinator::{ClusterOpts, LocalCluster};
use weips::downgrade::SwitchStrategy;
use weips::sample::WorkloadConfig;

fn artifacts_ready() -> bool {
    weips::runtime::default_artifacts_dir().join("manifest.json").exists()
}

fn cluster(threshold: f64) -> LocalCluster {
    LocalCluster::new(ClusterOpts {
        cluster: ClusterConfig {
            model_kind: ModelKind::Lr,
            master_shards: 2,
            slave_shards: 1,
            slave_replicas: 2,
            queue_partitions: 2,
            gather_mode: GatherMode::Realtime,
            ..Default::default()
        },
        workload: WorkloadConfig {
            ids_per_field: 300,
            zipf_s: 1.3,
            seed: 5,
            ..Default::default()
        },
        trigger_threshold: threshold,
        trigger_smooth: 3,
        switch_strategy: SwitchStrategy::LatestStable,
        ..Default::default()
    })
    .expect("cluster")
}

#[test]
fn corruption_detected_and_rolled_back() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let c = cluster(0.52);
    // Train long enough for window AUC to be meaningfully above 0.52.
    for _ in 0..120 {
        c.train_step().unwrap();
        c.sync_tick().unwrap();
    }
    c.flush_sync().unwrap();
    let healthy = c.monitor.snapshot();
    assert!(
        healthy.window_auc > 0.54,
        "model failed to learn (window auc {})",
        healthy.window_auc
    );
    let stable = c.checkpoint().unwrap();

    // Inject corruption; it streams to slaves like real updates.
    c.corrupt_model().unwrap();
    c.flush_sync().unwrap();

    // Keep training (progressive validation now sees corrupted pulls);
    // control ticks evaluate the smoothed trigger.
    let mut fired = None;
    for _ in 0..60 {
        c.train_step().unwrap();
        c.sync_tick().unwrap();
        if let Some(plan) = c.control_tick().unwrap() {
            fired = Some(plan);
            break;
        }
    }
    let plan = fired.expect("domino trigger never fired on corrupted model");
    // The corruption happened *after* the stable checkpoint with no newer
    // checkpoint in between, so the rollback lands back on `stable` (the
    // from/target versions coincide: live drift, not checkpoint lineage).
    assert_eq!(plan.target_version, stable);
    assert_eq!(c.vm.current(), stable);

    // Serving state equals the stable checkpoint's transformed weights and
    // training resumes cleanly.
    for _ in 0..5 {
        c.train_step().unwrap();
        c.sync_tick().unwrap();
    }
    let after = c.monitor.snapshot();
    assert!(after.samples > healthy.samples);
}

#[test]
fn plain_threshold_false_alarms_vs_smoothed() {
    // Unit-style comparison at integration scope: identical noisy metric
    // stream, plain trigger fires, smoothed does not (§4.3.2a).
    use weips::monitor::{PlainThreshold, SmoothedThreshold, Trigger};
    let noisy = [0.76, 0.69, 0.77, 0.75, 0.68, 0.78, 0.74, 0.69, 0.77];
    let mut plain = PlainThreshold { threshold: 0.70 };
    let mut smoothed = SmoothedThreshold::new(0.70, 3);
    let plain_fires = noisy.iter().filter(|v| plain.observe(**v)).count();
    let smoothed_fires = noisy.iter().filter(|v| smoothed.observe(**v)).count();
    assert!(plain_fires >= 3, "plain should false-alarm: {plain_fires}");
    assert_eq!(smoothed_fires, 0, "smoothed must ignore isolated dips");
}

#[test]
fn manual_version_switch() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let c = cluster(0.01); // trigger effectively disabled
    for _ in 0..30 {
        c.train_step().unwrap();
        c.sync_tick().unwrap();
    }
    c.flush_sync().unwrap();
    let v1 = c.checkpoint().unwrap();
    for _ in 0..30 {
        c.train_step().unwrap();
        c.sync_tick().unwrap();
    }
    c.flush_sync().unwrap();
    let v2 = c.checkpoint().unwrap();
    assert!(v2 > v1);
    // Operator pins the older version manually (§4.3.2 "the person can
    // specify the appropriate version ... manually").
    c.switch_version(v1).unwrap();
    assert_eq!(c.vm.current(), v1);
    // Serving still works on the pinned version.
    let preds = c.predict(&c.serving_requests(4)).unwrap();
    assert_eq!(preds.len(), 4);
}

#[test]
fn optimal_metric_strategy_picks_best_checkpoint() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let c = LocalCluster::new(ClusterOpts {
        cluster: ClusterConfig {
            model_kind: ModelKind::Lr,
            master_shards: 2,
            slave_shards: 1,
            slave_replicas: 1,
            queue_partitions: 2,
            gather_mode: GatherMode::Realtime,
            ..Default::default()
        },
        workload: WorkloadConfig { ids_per_field: 300, zipf_s: 1.3, seed: 9, ..Default::default() },
        switch_strategy: SwitchStrategy::OptimalMetric,
        trigger_threshold: 0.0,
        ..Default::default()
    })
    .unwrap();
    // Three checkpoints with improving metric.
    for _ in 0..3 {
        for _ in 0..40 {
            c.train_step().unwrap();
            c.sync_tick().unwrap();
        }
        c.flush_sync().unwrap();
        c.checkpoint().unwrap();
    }
    let plan = c
        .vm
        .plan(&c.store, SwitchStrategy::OptimalMetric)
        .expect("candidates exist");
    // The best-metric candidate should be the latest (metric improved).
    let manifests: Vec<_> = c
        .store
        .list_versions("ctr")
        .into_iter()
        .filter(|v| *v <= c.vm.current())
        .map(|v| c.store.load_manifest("ctr", v).unwrap())
        .collect();
    let best = manifests
        .iter()
        .max_by(|a, b| a.metric.partial_cmp(&b.metric).unwrap())
        .unwrap();
    assert_eq!(plan.target_version, best.version);
}
