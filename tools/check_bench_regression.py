#!/usr/bin/env python3
"""Gate CI on sync-pipeline bench regressions.

Usage: check_bench_regression.py <baseline.json> <current.json> [tolerance]

Compares the current `bench_sync_pipeline` smoke run against the committed
baseline and fails (exit 1) on a >tolerance (default 30%) regression in
gather/scatter throughput or push->visible latency.

Machine-speed normalization: absolute rows/s on a CI runner is not
comparable to the machine that recorded the baseline, so every comparison
is normalized by the sequential case (stripes=1, threads=0) of the same
stage: regression is judged on the *shape* of the scaling curve, which
cancels the host factor. Within one stage:

    factor = current_seq / baseline_seq
    fail if current[case] < (1 - tol) * factor * baseline[case]   (throughput)
    fail if current[case] > (1 + tol) * factor * baseline[case]   (latency)

Intra-run invariants are checked regardless of the baseline:
  - determinism record present with identical=true
  - scatter_coalesce: locks_per_row < locks_per_row_batchwise

A baseline containing a record {"stage": "meta", "provisional": true}
skips the cross-file comparison (used to seed the gate before the first
CI-measured artifact is promoted to baseline) while still enforcing the
intra-run invariants.
"""

import json
import sys


def load(path):
    with open(path) as f:
        return json.load(f)


def by_case(records, stage):
    out = {}
    for r in records:
        if r.get("stage") == stage:
            out[(r.get("stripes"), r.get("threads"))] = r
    return out


THROUGHPUT_STAGES = ["gather_snapshot", "gather_absorb", "scatter_apply", "scatter_coalesce"]
LATENCY_STAGES = ["push_to_visible"]
SEQ = (1, 0)


def check_intra_run(current):
    failures = []
    det = [r for r in current if r.get("stage") == "determinism"]
    if not det or not det[0].get("identical"):
        failures.append("determinism record missing or not identical")
    for r in current:
        if r.get("stage") != "scatter_coalesce":
            continue
        if not r["locks_per_row"] < r["locks_per_row_batchwise"]:
            failures.append(
                f"scatter_coalesce stripes={r['stripes']} threads={r['threads']}: "
                f"locks/row {r['locks_per_row']} !< batchwise {r['locks_per_row_batchwise']}"
            )
    return failures


def check_against_baseline(baseline, current, tol):
    failures = []
    for stage in THROUGHPUT_STAGES + LATENCY_STAGES:
        base = by_case(baseline, stage)
        cur = by_case(current, stage)
        if not base:
            continue
        key = "rows_per_sec" if stage in THROUGHPUT_STAGES else "ms_per_round"
        if SEQ not in base or SEQ not in cur:
            failures.append(f"{stage}: sequential reference case missing")
            continue
        factor = cur[SEQ][key] / base[SEQ][key]
        for case, b in base.items():
            if case == SEQ or case not in cur:
                continue
            expected = factor * b[key]
            got = cur[case][key]
            if stage in THROUGHPUT_STAGES:
                if got < (1.0 - tol) * expected:
                    failures.append(
                        f"{stage} stripes={case[0]} threads={case[1]}: "
                        f"{key} {got:.0f} < {(1.0 - tol) * expected:.0f} "
                        f"(baseline {b[key]:.0f} x host factor {factor:.2f})"
                    )
            else:
                if got > (1.0 + tol) * expected:
                    failures.append(
                        f"{stage} stripes={case[0]} threads={case[1]}: "
                        f"{key} {got:.3f} > {(1.0 + tol) * expected:.3f} "
                        f"(baseline {b[key]:.3f} x host factor {factor:.2f})"
                    )
    return failures


def main():
    if len(sys.argv) < 3:
        print(__doc__)
        return 2
    baseline = load(sys.argv[1])
    current = load(sys.argv[2])
    tol = float(sys.argv[3]) if len(sys.argv) > 3 else 0.30

    failures = check_intra_run(current)
    provisional = any(r.get("stage") == "meta" and r.get("provisional") for r in baseline)
    if provisional:
        print("baseline is provisional: skipping cross-run comparison "
              "(promote a CI artifact to ci/BENCH_sync_pipeline.baseline.json to arm it)")
    else:
        failures += check_against_baseline(baseline, current, tol)

    if failures:
        print(f"bench regression check FAILED ({len(failures)} issue(s)):")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("bench regression check passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
