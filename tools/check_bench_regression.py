#!/usr/bin/env python3
"""Gate CI on bench regressions.

Usage: check_bench_regression.py [--kind KIND] <baseline.json> <current.json> [tolerance]

Kinds:
  sync_pipeline (default) — compares the current `bench_sync_pipeline`
  smoke run against the committed baseline and fails (exit 1) on a
  >tolerance (default 30%) regression in gather/scatter throughput or
  push->visible latency.

  reshard — checks the E11 intra-run invariants (migrated state
  byte-identical to control, deterministic minimal-disruption rebalance,
  migrations actually move rows) and, against a non-provisional
  baseline, gates on host-independent shape regressions: the sealed
  hand-off window as a fraction of total migration time per
  slots_moved case, and the catch-up round count.

  serving — checks the E12 intra-run invariants (cached pulls
  byte-identical to uncached, hot-set hit rate >= 0.5, cached p99 at
  least 2x better than uncached, one-tick freshness) and, against a
  non-provisional baseline, gates on the already host-normalized
  shapes: the cached-vs-uncached p99 speedup, the hit rate, and the
  cached/uncached throughput ratio per thread count.

  substrate — checks the E13 zero-copy invariants (every stage present
  and byte_identical; the vectored-framing, mmap-load, and arena-pull
  wins are each >= 1.0x on at least 2 of the 3 stages; arena waste is
  zero after a pure-insert run) and, against a non-provisional
  baseline, gates on the per-stage win ratios — already same-host
  ratios of two measurements, so they compare across hosts without a
  sequential-case normalizer.

  tracing — checks the E14 update-journey tracing invariants (a
  fully-sampled push leaves a complete span chain of >= 6 distinct
  stages; sync-batch bytes identical with tracing off/sampled/on; the
  sampled-tracing overhead_frac on gather→scatter throughput is
  <= 0.05, i.e. at most 5%) and, against a non-provisional baseline,
  gates on the sampled/off throughput ratio — a same-host measurement
  pair that compares across hosts directly.

  alerts — checks the E15 cluster-health-engine invariants (the
  pending -> firing lifecycle engages against a breaching source and
  is journaled; sync-batch bytes identical with the evaluator off vs
  ticking; the evaluator overhead_frac on gather→scatter throughput is
  <= 0.01, i.e. at most 1%) and, against a non-provisional baseline,
  gates on the ticking/off throughput ratio — a same-host measurement
  pair that compares across hosts directly.

Machine-speed normalization: absolute rows/s on a CI runner is not
comparable to the machine that recorded the baseline, so every comparison
is normalized by the sequential case (stripes=1, threads=0) of the same
stage: regression is judged on the *shape* of the scaling curve, which
cancels the host factor. Within one stage:

    factor = current_seq / baseline_seq
    fail if current[case] < (1 - tol) * factor * baseline[case]   (throughput)
    fail if current[case] > (1 + tol) * factor * baseline[case]   (latency)

Intra-run invariants are checked regardless of the baseline:
  - determinism record present with identical=true
  - scatter_coalesce: locks_per_row < locks_per_row_batchwise

A baseline containing a record {"stage": "meta", "provisional": true}
skips the cross-file comparison (used to seed the gate before the first
CI-measured artifact is promoted to baseline) while still enforcing the
intra-run invariants.
"""

import json
import sys


def load(path):
    with open(path) as f:
        return json.load(f)


def by_case(records, stage):
    out = {}
    for r in records:
        if r.get("stage") == stage:
            out[(r.get("stripes"), r.get("threads"))] = r
    return out


THROUGHPUT_STAGES = ["gather_snapshot", "gather_absorb", "scatter_apply", "scatter_coalesce"]
LATENCY_STAGES = ["push_to_visible"]
SEQ = (1, 0)


def check_intra_run(current):
    failures = []
    det = [r for r in current if r.get("stage") == "determinism"]
    if not det or not det[0].get("identical"):
        failures.append("determinism record missing or not identical")
    for r in current:
        if r.get("stage") != "scatter_coalesce":
            continue
        if not r["locks_per_row"] < r["locks_per_row_batchwise"]:
            failures.append(
                f"scatter_coalesce stripes={r['stripes']} threads={r['threads']}: "
                f"locks/row {r['locks_per_row']} !< batchwise {r['locks_per_row_batchwise']}"
            )
    return failures


def check_against_baseline(baseline, current, tol):
    failures = []
    for stage in THROUGHPUT_STAGES + LATENCY_STAGES:
        base = by_case(baseline, stage)
        cur = by_case(current, stage)
        if not base:
            continue
        key = "rows_per_sec" if stage in THROUGHPUT_STAGES else "ms_per_round"
        if SEQ not in base or SEQ not in cur:
            failures.append(f"{stage}: sequential reference case missing")
            continue
        factor = cur[SEQ][key] / base[SEQ][key]
        for case, b in base.items():
            if case == SEQ or case not in cur:
                continue
            expected = factor * b[key]
            got = cur[case][key]
            if stage in THROUGHPUT_STAGES:
                if got < (1.0 - tol) * expected:
                    failures.append(
                        f"{stage} stripes={case[0]} threads={case[1]}: "
                        f"{key} {got:.0f} < {(1.0 - tol) * expected:.0f} "
                        f"(baseline {b[key]:.0f} x host factor {factor:.2f})"
                    )
            else:
                if got > (1.0 + tol) * expected:
                    failures.append(
                        f"{stage} stripes={case[0]} threads={case[1]}: "
                        f"{key} {got:.3f} > {(1.0 + tol) * expected:.3f} "
                        f"(baseline {b[key]:.3f} x host factor {factor:.2f})"
                    )
    return failures


RESHARD_STAGES = ("migration_pause", "catchup", "migrate_identity", "determinism")


def _num(rec, field, ctx, failures):
    """Numeric field accessor that reports schema drift as a gate failure
    instead of crashing the gate with a traceback."""
    v = rec.get(field)
    if isinstance(v, (int, float)) and not isinstance(v, bool):
        return v
    failures.append(f"{ctx}: field {field} missing or non-numeric ({v!r})")
    return None


def check_reshard_intra(current):
    """E11 invariants every reshard run must hold, baseline or not."""
    failures = []
    stages = {r.get("stage") for r in current}
    for need in RESHARD_STAGES:
        if need not in stages:
            failures.append(f"stage {need}: no records")
    for r in current:
        if r.get("stage") == "migrate_identity" and not r.get("byte_identical"):
            failures.append("migrate_identity record is not byte_identical")
        if r.get("stage") == "determinism" and not (
            r.get("identical") and r.get("minimal_disruption")
        ):
            failures.append("determinism record is not identical/minimal_disruption")
        if r.get("stage") == "migration_pause":
            if not r.get("purged_rows", 0) > 0:
                failures.append("migration_pause record moved zero rows")
            # Schema the armed gate depends on: refuse to promote (and
            # flag at run time) if it drifts.
            ctx = f"migration_pause slots_moved={r.get('slots_moved')}"
            _num(r, "sealed_ms", ctx, failures)
            _num(r, "total_ms", ctx, failures)
        if r.get("stage") == "catchup":
            _num(r, "rounds", "catchup", failures)
    return failures


def check_reshard_against_baseline(baseline, current, tol):
    """Host-independent shape gates: sealed-window fraction of total
    migration time per slots_moved case, and catch-up round count."""
    failures = []
    base = {r.get("slots_moved"): r for r in baseline if r.get("stage") == "migration_pause"}
    cur = {r.get("slots_moved"): r for r in current if r.get("stage") == "migration_pause"}
    for k, b in base.items():
        c = cur.get(k)
        if c is None:
            failures.append(f"migration_pause slots_moved={k}: missing from current run")
            continue
        ctx = f"migration_pause slots_moved={k}"
        fields = [
            _num(b, "sealed_ms", f"baseline {ctx}", failures),
            _num(b, "total_ms", f"baseline {ctx}", failures),
            _num(c, "sealed_ms", ctx, failures),
            _num(c, "total_ms", ctx, failures),
        ]
        if any(v is None for v in fields):
            continue
        b_sealed, b_total, c_sealed, c_total = fields
        b_ratio = b_sealed / max(b_total, 1e-9)
        c_ratio = c_sealed / max(c_total, 1e-9)
        # Absolute 0.05 headroom: tiny smoke runs make the ratio noisy.
        if c_ratio > (1.0 + tol) * b_ratio + 0.05:
            failures.append(
                f"{ctx}: sealed/total ratio "
                f"{c_ratio:.3f} > {(1.0 + tol) * b_ratio + 0.05:.3f} "
                f"(baseline {b_ratio:.3f})"
            )
    base_cat = [r for r in baseline if r.get("stage") == "catchup"]
    cur_cat = [r for r in current if r.get("stage") == "catchup"]
    if base_cat and cur_cat:
        b_rounds = _num(base_cat[0], "rounds", "baseline catchup", failures)
        c_rounds = _num(cur_cat[0], "rounds", "catchup", failures)
        if b_rounds is not None and c_rounds is not None and c_rounds > b_rounds + 2:
            failures.append(
                f"catchup: {c_rounds} rounds > baseline "
                f"{b_rounds} + 2 (convergence regressed)"
            )
    return failures


SERVING_STAGES = ("pull_latency", "throughput", "freshness")


def check_serving_intra(current):
    """E12 invariants every serving run must hold, baseline or not."""
    failures = []
    stages = {r.get("stage") for r in current}
    for need in SERVING_STAGES:
        if need not in stages:
            failures.append(f"stage {need}: no records")
    for r in current:
        if r.get("stage") == "pull_latency":
            if not r.get("byte_identical"):
                failures.append("pull_latency record is not byte_identical")
            hit = _num(r, "hit_rate", "pull_latency", failures)
            if hit is not None and hit < 0.5:
                failures.append(f"pull_latency: hit rate {hit:.3f} < 0.5")
            speedup = _num(r, "p99_speedup", "pull_latency", failures)
            if speedup is not None and speedup < 2.0:
                failures.append(f"pull_latency: cached p99 speedup {speedup:.2f}x < 2x")
        if r.get("stage") == "freshness" and not r.get("one_tick"):
            failures.append("freshness record lost the one-tick guarantee")
        if r.get("stage") == "throughput":
            _num(r, "pulls_per_sec", f"throughput threads={r.get('threads')}", failures)
    return failures


def check_serving_against_baseline(baseline, current, tol):
    """The serving shapes are ratios of two same-host measurements, so
    they compare across hosts without a sequential-case normalizer."""
    failures = []
    base = [r for r in baseline if r.get("stage") == "pull_latency"]
    cur = [r for r in current if r.get("stage") == "pull_latency"]
    if base and cur:
        for field, floor_tag in (("p99_speedup", "speedup"), ("hit_rate", "hit rate")):
            b = _num(base[0], field, "baseline pull_latency", failures)
            c = _num(cur[0], field, "pull_latency", failures)
            if b is None or c is None:
                continue
            if c < (1.0 - tol) * b:
                failures.append(
                    f"pull_latency: {floor_tag} {c:.3f} < "
                    f"{(1.0 - tol) * b:.3f} (baseline {b:.3f})"
                )
    def ratios(records):
        on = {r.get("threads"): r for r in records
              if r.get("stage") == "throughput" and r.get("cached")}
        off = {r.get("threads"): r for r in records
               if r.get("stage") == "throughput" and not r.get("cached")}
        out = {}
        for t, r in on.items():
            o = off.get(t)
            if o and o.get("pulls_per_sec"):
                out[t] = r.get("pulls_per_sec", 0) / o["pulls_per_sec"]
        return out
    b_ratio, c_ratio = ratios(baseline), ratios(current)
    for t, b in b_ratio.items():
        c = c_ratio.get(t)
        if c is None:
            failures.append(f"throughput threads={t}: missing from current run")
        elif c < (1.0 - tol) * b:
            failures.append(
                f"throughput threads={t}: cached/uncached ratio "
                f"{c:.2f} < {(1.0 - tol) * b:.2f} (baseline {b:.2f})"
            )
    return failures


SUBSTRATE_STAGES = ("framing", "mmap_load", "arena_pull", "uring_identity")
SUBSTRATE_WIN_STAGES = ("framing", "mmap_load", "arena_pull")


def check_substrate_intra(current):
    """E13 invariants every substrate run must hold, baseline or not."""
    failures = []
    stages = {r.get("stage") for r in current}
    for need in SUBSTRATE_STAGES:
        if need not in stages:
            failures.append(f"stage {need}: no records")
    wins = {}
    for r in current:
        stage = r.get("stage")
        if stage in SUBSTRATE_STAGES and not r.get("byte_identical"):
            failures.append(f"{stage} record is not byte_identical")
        if stage in SUBSTRATE_WIN_STAGES:
            w = _num(r, "win", stage, failures)
            if w is not None:
                wins[stage] = w
        if stage == "arena_pull":
            waste = _num(r, "arena_waste_floats", "arena_pull", failures)
            if waste is not None and waste != 0:
                failures.append(f"arena_pull: {waste} wasted floats after pure inserts")
        if stage == "uring_identity":
            # Availability is informational (sandboxes may deny rings),
            # but the field itself must be present and boolean.
            if not isinstance(r.get("uring_available"), bool):
                failures.append("uring_identity: uring_available missing or non-boolean")
    winning = sum(1 for w in wins.values() if w >= 1.0)
    if len(wins) == len(SUBSTRATE_WIN_STAGES) and winning < 2:
        failures.append(
            "zero-copy wins on only "
            f"{winning}/3 stages ({', '.join(f'{s}={w:.2f}x' for s, w in sorted(wins.items()))})"
        )
    return failures


def check_substrate_against_baseline(baseline, current, tol):
    """Win ratios are same-host measurement pairs, so they compare
    across hosts directly."""
    failures = []
    base = {r.get("stage"): r for r in baseline if r.get("stage") in SUBSTRATE_WIN_STAGES}
    cur = {r.get("stage"): r for r in current if r.get("stage") in SUBSTRATE_WIN_STAGES}
    for stage, b in base.items():
        c = cur.get(stage)
        if c is None:
            failures.append(f"{stage}: missing from current run")
            continue
        b_win = _num(b, "win", f"baseline {stage}", failures)
        c_win = _num(c, "win", stage, failures)
        if b_win is None or c_win is None:
            continue
        # Absolute 0.05 headroom: wins near 1.0x are noisy on small runs.
        if c_win < (1.0 - tol) * b_win - 0.05:
            failures.append(
                f"{stage}: win {c_win:.3f}x < "
                f"{(1.0 - tol) * b_win - 0.05:.3f}x (baseline {b_win:.3f}x)"
            )
    return failures


TRACING_STAGES = ("pipeline_throughput", "overhead", "chain", "byte_identity")
TRACING_MAX_OVERHEAD = 0.05


def check_tracing_intra(current):
    """E14 invariants every tracing run must hold, baseline or not."""
    failures = []
    stages = {r.get("stage") for r in current}
    for need in TRACING_STAGES:
        if need not in stages:
            failures.append(f"stage {need}: no records")
    for r in current:
        if r.get("stage") == "chain":
            if not r.get("complete"):
                failures.append("chain record is not complete")
            n = _num(r, "distinct_stages", "chain", failures)
            if n is not None and n < 6:
                failures.append(f"chain: only {n} distinct stages (< 6)")
        if r.get("stage") == "byte_identity" and not r.get("identical"):
            failures.append("byte_identity record is not identical")
        if r.get("stage") == "overhead":
            frac = _num(r, "overhead_frac", "overhead", failures)
            if frac is not None and frac > TRACING_MAX_OVERHEAD:
                failures.append(
                    f"overhead: sampled tracing costs {frac:.1%} of "
                    f"gather/scatter throughput (> {TRACING_MAX_OVERHEAD:.0%})"
                )
    return failures


def check_tracing_against_baseline(baseline, current, tol):
    """The sampled/off throughput ratio is a same-host measurement pair,
    so it compares across hosts directly."""
    failures = []
    base = [r for r in baseline if r.get("stage") == "overhead"]
    cur = [r for r in current if r.get("stage") == "overhead"]
    if base and cur:
        fields = [
            _num(base[0], "off_rows_per_sec", "baseline overhead", failures),
            _num(base[0], "sampled_rows_per_sec", "baseline overhead", failures),
            _num(cur[0], "off_rows_per_sec", "overhead", failures),
            _num(cur[0], "sampled_rows_per_sec", "overhead", failures),
        ]
        if not any(v is None for v in fields):
            b_off, b_on, c_off, c_on = fields
            b_ratio = b_on / max(b_off, 1e-9)
            c_ratio = c_on / max(c_off, 1e-9)
            # Absolute 0.05 headroom: ratios near 1.0 are noisy on small
            # smoke runs.
            if c_ratio < (1.0 - tol) * b_ratio - 0.05:
                failures.append(
                    f"overhead: sampled/off ratio {c_ratio:.3f} < "
                    f"{(1.0 - tol) * b_ratio - 0.05:.3f} (baseline {b_ratio:.3f})"
                )
    return failures


ALERTS_STAGES = (
    "pipeline_throughput",
    "overhead",
    "eval_cost",
    "lifecycle",
    "byte_identity",
)
ALERTS_MAX_OVERHEAD = 0.01


def check_alerts_intra(current):
    """E15 invariants every alerts run must hold, baseline or not."""
    failures = []
    stages = {r.get("stage") for r in current}
    for need in ALERTS_STAGES:
        if need not in stages:
            failures.append(f"stage {need}: no records")
    for r in current:
        if r.get("stage") == "lifecycle":
            if not r.get("fired"):
                failures.append("lifecycle record never reached firing")
            if not r.get("journaled"):
                failures.append("lifecycle record missing from the journal")
        if r.get("stage") == "byte_identity" and not r.get("identical"):
            failures.append("byte_identity record is not identical")
        if r.get("stage") == "overhead":
            frac = _num(r, "overhead_frac", "overhead", failures)
            if frac is not None and frac > ALERTS_MAX_OVERHEAD:
                failures.append(
                    f"overhead: alert evaluator costs {frac:.1%} of "
                    f"gather/scatter throughput (> {ALERTS_MAX_OVERHEAD:.0%})"
                )
    return failures


def check_alerts_against_baseline(baseline, current, tol):
    """The ticking/off throughput ratio is a same-host measurement pair,
    so it compares across hosts directly."""
    failures = []
    base = [r for r in baseline if r.get("stage") == "overhead"]
    cur = [r for r in current if r.get("stage") == "overhead"]
    if base and cur:
        fields = [
            _num(base[0], "off_rows_per_sec", "baseline overhead", failures),
            _num(base[0], "ticking_rows_per_sec", "baseline overhead", failures),
            _num(cur[0], "off_rows_per_sec", "overhead", failures),
            _num(cur[0], "ticking_rows_per_sec", "overhead", failures),
        ]
        if not any(v is None for v in fields):
            b_off, b_on, c_off, c_on = fields
            b_ratio = b_on / max(b_off, 1e-9)
            c_ratio = c_on / max(c_off, 1e-9)
            # Absolute 0.05 headroom: ratios near 1.0 are noisy on small
            # smoke runs.
            if c_ratio < (1.0 - tol) * b_ratio - 0.05:
                failures.append(
                    f"overhead: ticking/off ratio {c_ratio:.3f} < "
                    f"{(1.0 - tol) * b_ratio - 0.05:.3f} (baseline {b_ratio:.3f})"
                )
    return failures


def main():
    args = sys.argv[1:]
    kind = "sync_pipeline"
    if args and args[0] == "--kind":
        if len(args) < 2 or args[1] not in (
            "sync_pipeline",
            "reshard",
            "serving",
            "substrate",
            "tracing",
            "alerts",
        ):
            print(__doc__)
            return 2
        kind = args[1]
        args = args[2:]
    if len(args) < 2:
        print(__doc__)
        return 2
    baseline = load(args[0])
    current = load(args[1])
    tol = float(args[2]) if len(args) > 2 else 0.30

    if kind == "reshard":
        failures = check_reshard_intra(current)
    elif kind == "serving":
        failures = check_serving_intra(current)
    elif kind == "substrate":
        failures = check_substrate_intra(current)
    elif kind == "tracing":
        failures = check_tracing_intra(current)
    elif kind == "alerts":
        failures = check_alerts_intra(current)
    else:
        failures = check_intra_run(current)
    provisional = any(r.get("stage") == "meta" and r.get("provisional") for r in baseline)
    if provisional:
        print(f"baseline is provisional: skipping cross-run comparison "
              f"(promote a CI artifact to {args[0]} to arm it)")
    elif kind == "reshard":
        failures += check_reshard_against_baseline(baseline, current, tol)
    elif kind == "serving":
        failures += check_serving_against_baseline(baseline, current, tol)
    elif kind == "substrate":
        failures += check_substrate_against_baseline(baseline, current, tol)
    elif kind == "tracing":
        failures += check_tracing_against_baseline(baseline, current, tol)
    elif kind == "alerts":
        failures += check_alerts_against_baseline(baseline, current, tol)
    else:
        failures += check_against_baseline(baseline, current, tol)

    if failures:
        print(f"bench regression check FAILED ({len(failures)} issue(s)):")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("bench regression check passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
