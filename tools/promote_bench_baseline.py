#!/usr/bin/env python3
"""Validate and promote a measured bench artifact to a committed baseline.

Usage:
  promote_bench_baseline.py [--kind KIND] <candidate.json> <baseline-path>
      Validate <candidate.json> (a BENCH_*.json produced by a trusted
      run) and install it at <baseline-path>, arming the cross-run gate
      (sync_pipeline) or pinning the known-good invariants run (reshard).

  promote_bench_baseline.py --provisional-check <baseline-path>
      Exit 0 iff the committed baseline is still the provisional seed
      (i.e. promotion is wanted). CI uses this to self-arm on the first
      trusted main-branch run.

Kinds:
  sync_pipeline (default) — validates the regression-gate shape:
    - parses as a JSON list of records;
    - not itself provisional;
    - every gated stage has its sequential reference case
      (stripes=1, threads=0) — check_bench_regression normalizes by it;
    - the intra-run invariants hold (determinism identical, coalescing
      amortizes locks), so a broken run can never become the baseline.

  reshard — validates the E11 invariants run:
    - every stage present (migration_pause, catchup, migrate_identity,
      determinism);
    - the identity record is byte_identical and the determinism record is
      identical + minimal_disruption;
    - not itself provisional.

  serving — validates the E12 invariants run:
    - every stage present (pull_latency, throughput, freshness);
    - cached pulls byte-identical, hit rate >= 0.5, p99 speedup >= 2x,
      one-tick freshness held;
    - not itself provisional.

  substrate — validates the E13 zero-copy invariants run:
    - every stage present (framing, mmap_load, arena_pull,
      uring_identity), each byte_identical;
    - zero-copy win >= 1.0x on at least 2 of the 3 measured stages and
      zero arena waste;
    - not itself provisional.

  tracing — validates the E14 update-journey tracing run:
    - every stage present (pipeline_throughput, overhead, chain,
      byte_identity);
    - the sampled span chain is complete with >= 6 distinct stages,
      sync-batch bytes identical across sample rates, and the sampled
      overhead_frac <= 0.05;
    - not itself provisional.

  alerts — validates the E15 cluster-health-engine run:
    - every stage present (pipeline_throughput, overhead, eval_cost,
      lifecycle, byte_identity);
    - the pending -> firing lifecycle engaged and was journaled,
      sync-batch bytes identical with the evaluator off vs ticking, and
      the evaluator overhead_frac <= 0.01;
    - not itself provisional.
"""

import json
import sys

sys.path.insert(0, __import__("os").path.dirname(__file__))
from check_bench_regression import (  # noqa: E402
    LATENCY_STAGES,
    SEQ,
    THROUGHPUT_STAGES,
    by_case,
    check_intra_run,
    check_reshard_intra,
    check_serving_intra,
    check_alerts_intra,
    check_substrate_intra,
    check_tracing_intra,
)


def is_provisional(records):
    return any(r.get("stage") == "meta" and r.get("provisional") for r in records)


def validate_sync_pipeline(candidate):
    errors = check_intra_run(candidate)
    for stage in THROUGHPUT_STAGES + LATENCY_STAGES:
        cases = by_case(candidate, stage)
        if not cases:
            errors.append(f"stage {stage}: no records")
        elif SEQ not in cases:
            errors.append(f"stage {stage}: sequential reference case {SEQ} missing")
    return errors


def validate_reshard(candidate):
    return check_reshard_intra(candidate)


def validate_serving(candidate):
    return check_serving_intra(candidate)


def validate_substrate(candidate):
    return check_substrate_intra(candidate)


def validate_tracing(candidate):
    return check_tracing_intra(candidate)


def validate_alerts(candidate):
    return check_alerts_intra(candidate)


VALIDATORS = {
    "sync_pipeline": validate_sync_pipeline,
    "reshard": validate_reshard,
    "serving": validate_serving,
    "substrate": validate_substrate,
    "tracing": validate_tracing,
    "alerts": validate_alerts,
}


def main():
    args = sys.argv[1:]
    kind = "sync_pipeline"
    if args and args[0] == "--kind":
        if len(args) < 2 or args[1] not in VALIDATORS:
            print(__doc__)
            return 2
        kind = args[1]
        args = args[2:]
    if len(args) == 2 and args[0] == "--provisional-check":
        with open(args[1]) as f:
            return 0 if is_provisional(json.load(f)) else 1
    if len(args) != 2:
        print(__doc__)
        return 2
    candidate_path, baseline_path = args
    with open(candidate_path) as f:
        candidate = json.load(f)
    errors = VALIDATORS[kind](candidate)
    if is_provisional(candidate):
        errors.append("candidate is itself a provisional seed")
    if errors:
        print(f"candidate {candidate_path} rejected ({len(errors)} issue(s)):")
        for e in errors:
            print(f"  - {e}")
        return 1
    with open(baseline_path, "w") as f:
        json.dump(candidate, f, indent=1)
        f.write("\n")
    print(f"promoted {candidate_path} -> {baseline_path} "
          f"({len(candidate)} records, kind={kind}); the baseline is armed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
