#!/usr/bin/env python3
"""Validate and promote a measured bench_sync_pipeline artifact to the
committed regression baseline.

Usage:
  promote_bench_baseline.py <candidate.json> <baseline-path>
      Validate <candidate.json> (a BENCH_sync_pipeline.json produced by a
      trusted run) and install it at <baseline-path>, arming the
      cross-run regression gate in tools/check_bench_regression.py.

  promote_bench_baseline.py --provisional-check <baseline-path>
      Exit 0 iff the committed baseline is still the provisional seed
      (i.e. promotion is wanted). CI uses this to self-arm the gate on
      the first trusted main-branch run.

Validation before installing:
  - parses as a JSON list of records;
  - not itself provisional;
  - every gated stage has its sequential reference case
    (stripes=1, threads=0) — check_bench_regression normalizes by it;
  - the intra-run invariants hold (determinism identical, coalescing
    amortizes locks), so a broken run can never become the baseline.
"""

import json
import sys

sys.path.insert(0, __import__("os").path.dirname(__file__))
from check_bench_regression import (  # noqa: E402
    LATENCY_STAGES,
    SEQ,
    THROUGHPUT_STAGES,
    by_case,
    check_intra_run,
)


def is_provisional(records):
    return any(r.get("stage") == "meta" and r.get("provisional") for r in records)


def validate(candidate):
    errors = check_intra_run(candidate)
    if is_provisional(candidate):
        errors.append("candidate is itself a provisional seed")
    for stage in THROUGHPUT_STAGES + LATENCY_STAGES:
        cases = by_case(candidate, stage)
        if not cases:
            errors.append(f"stage {stage}: no records")
        elif SEQ not in cases:
            errors.append(f"stage {stage}: sequential reference case {SEQ} missing")
    return errors


def main():
    args = sys.argv[1:]
    if len(args) == 2 and args[0] == "--provisional-check":
        with open(args[1]) as f:
            return 0 if is_provisional(json.load(f)) else 1
    if len(args) != 2:
        print(__doc__)
        return 2
    candidate_path, baseline_path = args
    with open(candidate_path) as f:
        candidate = json.load(f)
    errors = validate(candidate)
    if errors:
        print(f"candidate {candidate_path} rejected ({len(errors)} issue(s)):")
        for e in errors:
            print(f"  - {e}")
        return 1
    with open(baseline_path, "w") as f:
        json.dump(candidate, f, indent=1)
        f.write("\n")
    print(f"promoted {candidate_path} -> {baseline_path} "
          f"({len(candidate)} records); the regression gate is armed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
